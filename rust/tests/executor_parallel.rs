//! Executor acceptance suite (DESIGN.md §11):
//!
//! * **Determinism under parallelism** — `run_path`, `cross_validate` and
//!   `run_path_sharded` (prefetch enabled) produce bit-identical
//!   solutions, keep-counts and col-ops at one execution stream vs four.
//!   Accumulation order is per-column by construction; these tests pin it
//!   so the executor can never silently reorder.
//! * **Nested oversubscription** — cv → fista → ops composes to at most
//!   `num_threads()` live execution streams (the old spawn-per-layer
//!   stack multiplied workers per level).
//! * **Zero steady-state spawns** — after the pool is up, a full λ-path
//!   (and a sharded one, prefetch included) performs no
//!   `std::thread::spawn` at all.
//!
//! Every test takes the process-wide `EXCLUSIVE` lock: the spawn counter
//! and the peak-activity gauge are global, and the serial-cutoff env
//! override must not leak between tests.

use mtfl_dpc::coordinator::cv::cross_validate;
use mtfl_dpc::coordinator::lambda_grid;
use mtfl_dpc::coordinator::path::{
    run_path, run_path_sharded, EngineKind, PathOptions, PathRunResult, ScreenerKind,
    ShardRunResult,
};
use mtfl_dpc::data::io::save_sharded;
use mtfl_dpc::data::synthetic::{synthetic1, SynthOptions};
use mtfl_dpc::data::{Dataset, ShardedDataset};
use mtfl_dpc::solver::SolveOptions;
use mtfl_dpc::testing::scale;
use mtfl_dpc::util::executor;
use mtfl_dpc::util::num_threads;
use std::path::PathBuf;
use std::sync::Mutex;

static EXCLUSIVE: Mutex<()> = Mutex::new(());

fn exclusive() -> std::sync::MutexGuard<'static, ()> {
    EXCLUSIVE.lock().unwrap_or_else(|e| e.into_inner())
}

/// Zero the serial cutoff for the guard's lifetime so even the small test
/// problems exercise the pooled sweep paths; restores the prior value.
struct ZeroCutoff(Option<String>);

impl ZeroCutoff {
    fn set() -> Self {
        let old = std::env::var("MTFL_SERIAL_CUTOFF").ok();
        std::env::set_var("MTFL_SERIAL_CUTOFF", "0");
        ZeroCutoff(old)
    }
}

impl Drop for ZeroCutoff {
    fn drop(&mut self) {
        match self.0.take() {
            Some(v) => std::env::set_var("MTFL_SERIAL_CUTOFF", v),
            None => std::env::remove_var("MTFL_SERIAL_CUTOFF"),
        }
    }
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("mtfl_exec_{}_{}", std::process::id(), name))
}

fn problem() -> Dataset {
    synthetic1(&SynthOptions {
        t: 3,
        n: scale::n(14),
        d: scale::d(120),
        support_frac: 0.08,
        noise: 0.05,
        seed: 61,
    })
    .0
}

/// Bytes per shard block, sized off the (possibly shrunk) sample count so
/// the sharded runs always split into several blocks.
fn shard_block_bytes() -> usize {
    scale::n(14) * 3 * 4 * 8
}

fn path_opts() -> PathOptions {
    PathOptions {
        ratios: lambda_grid(scale::grid(10), 1.0, 0.05),
        solve: SolveOptions { tol: 1e-7, dynamic_every: 7, ..Default::default() },
        screener: ScreenerKind::Dpc,
        ..Default::default()
    }
}

fn assert_bits_eq(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: bit mismatch at {i}: {x} vs {y}");
    }
}

fn assert_runs_identical(serial: &PathRunResult, pooled: &PathRunResult, what: &str) {
    assert_bits_eq(&serial.last_w, &pooled.last_w, &format!("{what}: last_w"));
    assert_eq!(serial.lam_max.to_bits(), pooled.lam_max.to_bits(), "{what}: lam_max");
    assert_eq!(serial.records.len(), pooled.records.len());
    for (s, p) in serial.records.iter().zip(&pooled.records) {
        let at = format!("{what} at ratio {}", s.ratio);
        assert_eq!(s.kept, p.kept, "{at}: kept");
        assert_eq!(s.rejected, p.rejected, "{at}: rejected");
        assert_eq!(s.inactive, p.inactive, "{at}: inactive");
        assert_eq!(s.col_ops, p.col_ops, "{at}: col_ops");
        assert_eq!(s.solver_iters, p.solver_iters, "{at}: iters");
        assert_eq!(s.obj.to_bits(), p.obj.to_bits(), "{at}: obj");
        assert_eq!(s.gap.to_bits(), p.gap.to_bits(), "{at}: gap");
    }
}

fn run_at_cap(ds: &Dataset, opts: &PathOptions, cap: usize) -> PathRunResult {
    executor::with_worker_cap(cap, || run_path(ds, opts, &EngineKind::Exact).unwrap())
}

#[test]
fn run_path_bit_identical_serial_vs_pooled_dense() {
    let _x = exclusive();
    let _z = ZeroCutoff::set();
    let ds = problem();
    let serial = run_at_cap(&ds, &path_opts(), 1);
    let pooled = run_at_cap(&ds, &path_opts(), 4);
    assert_runs_identical(&serial, &pooled, "dense");
    // sanity: the grid actually screened and solved nontrivially (the
    // shrunk Miri/loom sizes are too small to guarantee both at once)
    if !scale::shrunk() {
        assert!(serial.records.iter().any(|r| r.rejected > 0 && r.kept > 0));
    }
}

#[test]
fn run_path_bit_identical_serial_vs_pooled_csc() {
    let _x = exclusive();
    let _z = ZeroCutoff::set();
    let ds = problem().to_csc();
    // GapSafe exercises a different screener sweep than the dense test
    let opts = PathOptions { screener: ScreenerKind::GapSafe, ..path_opts() };
    let serial = run_at_cap(&ds, &opts, 1);
    let pooled = run_at_cap(&ds, &opts, 4);
    assert_runs_identical(&serial, &pooled, "csc");
}

#[test]
fn run_path_sharded_bit_identical_serial_vs_pooled_with_prefetch() {
    let _x = exclusive();
    let _z = ZeroCutoff::set();
    let ds = problem();
    let p = tmp("determinism.mtd3");
    // narrow blocks so the prefetch pipeline really crosses boundaries
    save_sharded(&ds, &p, shard_block_bytes()).unwrap();
    let run = |cap: usize| -> ShardRunResult {
        let sh = ShardedDataset::open(&p).unwrap();
        assert!(sh.n_blocks() > 2, "want multiple blocks, got {}", sh.n_blocks());
        assert!(sh.prefetch_enabled(), "prefetch must default on");
        executor::with_worker_cap(cap, || run_path_sharded(&sh, &path_opts()).unwrap())
    };
    let serial = run(1);
    let pooled = run(4);
    std::fs::remove_file(&p).ok();
    assert_runs_identical(&serial.path, &pooled.path, "sharded");
    assert_eq!(serial.materialized_bytes, pooled.materialized_bytes);
    let pf = pooled.prefetch;
    assert!(pf.hits <= pf.issued, "hits {} > issued {}", pf.hits, pf.issued);
    if num_threads() > 1 {
        assert!(pf.issued > 0, "pooled sharded run never engaged the pipeline");
    }
}

#[test]
fn cross_validate_bit_identical_serial_vs_pooled() {
    let _x = exclusive();
    let _z = ZeroCutoff::set();
    let ds = synthetic1(&SynthOptions {
        t: 3,
        n: scale::n(30),
        d: scale::d(60),
        support_frac: 0.1,
        noise: 0.3,
        seed: 62,
    })
    .0;
    let opts = PathOptions {
        ratios: lambda_grid(scale::grid(8), 1.0, 0.05),
        solve: SolveOptions { tol: 1e-7, ..Default::default() },
        screener: ScreenerKind::Dpc,
        ..Default::default()
    };
    let serial = executor::with_worker_cap(1, || cross_validate(&ds, &opts, 3, 0).unwrap());
    let pooled = executor::with_worker_cap(4, || cross_validate(&ds, &opts, 3, 0).unwrap());
    assert_bits_eq(&serial.mse, &pooled.mse, "cv mse curve");
    assert_eq!(serial.best_index, pooled.best_index);
    assert_eq!(serial.col_ops, pooled.col_ops, "cv col_ops");
    assert_eq!(serial.fold_col_ops, pooled.fold_col_ops, "per-fold col_ops");
}

#[test]
fn nested_cv_fista_ops_never_exceeds_num_threads() {
    let _x = exclusive();
    let _z = ZeroCutoff::set();
    let ds = synthetic1(&SynthOptions {
        t: 3,
        n: scale::n(30),
        d: scale::d(80),
        support_frac: 0.1,
        noise: 0.3,
        seed: 63,
    })
    .0;
    let opts = PathOptions {
        ratios: lambda_grid(scale::grid(6), 1.0, 0.05),
        solve: SolveOptions { tol: 1e-6, dynamic_every: 5, ..Default::default() },
        screener: ScreenerKind::Dpc,
        ..Default::default()
    };
    executor::ensure_init();
    executor::reset_peak_active();
    // cv fans folds across the pool; each fold runs FISTA whose lipschitz
    // fan-out and ops sweeps must inline on the fold's worker — the
    // spawn-per-layer era multiplied these into W³ threads
    cross_validate(&ds, &opts, 3, 0).unwrap();
    let peak = executor::peak_active();
    assert!(
        peak <= num_threads(),
        "cv→fista→ops composed to {peak} live execution streams \
         (num_threads() = {})",
        num_threads()
    );
}

#[test]
fn steady_state_path_performs_zero_spawns() {
    let _x = exclusive();
    let _z = ZeroCutoff::set();
    let ds = problem();
    executor::ensure_init();
    // warm one scope so lazy bits are settled, then freeze the counter
    let _ = executor::with_worker_cap(4, || {
        mtfl_dpc::ops::gscore(&ds, &mtfl_dpc::ops::y64(&ds))
    });
    let spawns_before = executor::spawn_count();

    let res = run_path(&ds, &path_opts(), &EngineKind::Exact).unwrap();
    assert_eq!(res.records.len(), scale::grid(10));

    let p = tmp("zerospawn.mtd3");
    save_sharded(&ds, &p, shard_block_bytes()).unwrap();
    let sh = ShardedDataset::open(&p).unwrap();
    let shard_res = run_path_sharded(&sh, &path_opts()).unwrap();
    std::fs::remove_file(&p).ok();
    assert_eq!(shard_res.path.records.len(), scale::grid(10));

    assert_eq!(
        executor::spawn_count(),
        spawns_before,
        "the steady-state per-λ loop spawned OS threads"
    );
}
