//! Distributed shard-sweep integration (DESIGN.md §16): a coordinator
//! plus real `repro worker` subprocesses must reproduce the
//! single-process sharded path **bitwise** — identical keep-sets,
//! identical objective/gap bits, identical final solutions — at any
//! worker count, under an injected worker failure mid-sweep, and across
//! a checkpoint interrupt/resume. Corrupted checkpoints must fail with
//! an error that names `--checkpoint`.

use mtfl_dpc::coordinator::checkpoint::step_path;
use mtfl_dpc::coordinator::distrib::{Coordinator, DistribSweeps};
use mtfl_dpc::coordinator::lambda_grid;
use mtfl_dpc::coordinator::path::{
    run_path_sharded, run_path_sharded_checkpointed, run_path_sharded_core, FnObserver,
    LambdaRecord, PathOptions, ScreenerKind, ShardRunResult,
};
use mtfl_dpc::coordinator::{run_path_distributed, CheckpointCfg, DistribOptions};
use mtfl_dpc::data::io::save_sharded;
use mtfl_dpc::data::synthetic::{synthetic1, SynthOptions};
use mtfl_dpc::data::{Dataset, ShardedDataset};
use mtfl_dpc::solver::SolveOptions;
use mtfl_dpc::PenaltyKind;
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("mtfl_distrib_{}_{}", std::process::id(), name))
}

fn dense_problem() -> Dataset {
    synthetic1(&SynthOptions {
        t: 3,
        n: 14,
        d: 120,
        support_frac: 0.08,
        noise: 0.05,
        seed: 77,
    })
    .0
}

fn shard_of(ds: &Dataset, tag: &str) -> (ShardedDataset, PathBuf) {
    let p = tmp(tag);
    save_sharded(ds, &p, 2500).unwrap();
    (ShardedDataset::open(&p).unwrap(), p)
}

fn path_opts(screener: ScreenerKind, pen: PenaltyKind) -> PathOptions {
    let mut opts = PathOptions {
        ratios: lambda_grid(10, 1.0, 0.05),
        solve: SolveOptions { tol: 1e-7, ..Default::default() },
        screener,
        ..Default::default()
    };
    opts.solve.penalty = pen;
    opts
}

fn noop() -> FnObserver<impl FnMut(f64, f64, &[f64], &LambdaRecord)> {
    FnObserver(|_: f64, _: f64, _: &[f64], _: &LambdaRecord| {})
}

/// Grab an ephemeral port the OS considers free right now. The
/// bind-and-drop race is theoretical at test scale, and it lets the
/// workers be launched before the coordinator binds (they retry).
fn free_addr() -> String {
    let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = l.local_addr().unwrap().to_string();
    drop(l);
    addr
}

fn spawn_worker(addr: &str) -> Child {
    Command::new(env!("CARGO_BIN_EXE_repro"))
        .args(["worker", "--connect", addr, "--cache-mb", "64"])
        .stdin(Stdio::null())
        .stdout(Stdio::null())
        .spawn()
        .expect("spawn repro worker")
}

/// records + final solution must agree bit for bit.
fn assert_bitwise(a: &ShardRunResult, b: &ShardRunResult, what: &str) {
    assert_eq!(a.path.lam_max.to_bits(), b.path.lam_max.to_bits(), "{what}: lam_max");
    assert_eq!(a.path.records.len(), b.path.records.len(), "{what}: record count");
    for (x, y) in a.path.records.iter().zip(&b.path.records) {
        assert_eq!(x.lam.to_bits(), y.lam.to_bits(), "{what}: lam at {}", x.ratio);
        assert_eq!(x.kept, y.kept, "{what}: kept at ratio {}", x.ratio);
        assert_eq!(x.rejected, y.rejected, "{what}: rejected at ratio {}", x.ratio);
        assert_eq!(x.obj.to_bits(), y.obj.to_bits(), "{what}: obj at ratio {}", x.ratio);
        assert_eq!(x.gap.to_bits(), y.gap.to_bits(), "{what}: gap at ratio {}", x.ratio);
    }
    assert_eq!(a.path.last_w.len(), b.path.last_w.len());
    for (i, (x, y)) in a.path.last_w.iter().zip(&b.path.last_w).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: last_w[{i}]");
    }
}

/// Run the path distributed over `workers` externally launched worker
/// processes (the `--no-spawn` topology, which is also what CI uses
/// implicitly through `--distributed N`'s self-spawning).
fn distributed_run(
    sh: &ShardedDataset,
    shard_path: &PathBuf,
    opts: &PathOptions,
    workers: usize,
) -> ShardRunResult {
    let addr = free_addr();
    let mut children: Vec<Child> = (0..workers).map(|_| spawn_worker(&addr)).collect();
    let dopts = DistribOptions {
        workers,
        listen: addr,
        spawn_local: false,
        worker_timeout_secs: 60.0,
        cache_mb: 64,
    };
    let mut obs = noop();
    let res = run_path_distributed(sh, shard_path, opts, &dopts, &mut obs, None).unwrap();
    for c in &mut children {
        let _ = c.wait();
    }
    res
}

#[test]
fn distributed_matches_single_process_bitwise_at_widths_1_and_4() {
    let ds = dense_problem();
    let (sh, p) = shard_of(&ds, "parity.mtd3");
    assert!(sh.n_blocks() > 2, "want a multi-block shard, got {}", sh.n_blocks());
    let opts = path_opts(ScreenerKind::Dpc, PenaltyKind::L21);
    let single = run_path_sharded(&sh, &opts).unwrap();
    for workers in [1usize, 4] {
        let dist = distributed_run(&sh, &p, &opts, workers);
        assert_bitwise(&single, &dist, &format!("{workers} workers"));
        // the ledger accounts for every block exactly once
        let assigned: usize = dist.workers.iter().map(|w| w.blocks).sum();
        assert_eq!(assigned, sh.n_blocks(), "{workers} workers: block coverage");
        assert!(
            dist.workers.iter().all(|w| w.sweeps > 0),
            "{workers} workers: every worker should have swept something"
        );
    }
    std::fs::remove_file(&p).ok();
}

#[test]
fn distributed_streams_non_l21_penalties_too() {
    // satellite of the Penalty::infeasibility seam: the distributed
    // infeas sweep is penalty-generic, so sgl + gap screening must also
    // match the single-process run bitwise
    let ds = dense_problem();
    let (sh, p) = shard_of(&ds, "parity_sgl.mtd3");
    let opts = path_opts(ScreenerKind::GapSafe, PenaltyKind::Sgl { alpha: 0.5 });
    let single = run_path_sharded(&sh, &opts).unwrap();
    let dist = distributed_run(&sh, &p, &opts, 2);
    assert_bitwise(&single, &dist, "sgl/gap 2 workers");
    std::fs::remove_file(&p).ok();
}

#[test]
fn a_worker_death_mid_sweep_reassigns_and_stays_bitwise() {
    // 2 real workers + 1 scripted fake: the fake answers hello (the
    // reply is pre-written into the socket before the coordinator even
    // asks — per-connection streams make that legal) and then FINs its
    // write side, so its first sweep request reads EOF at the
    // coordinator. Its block ranges must be reassigned to the survivors
    // and the merged result must not change by a single bit.
    let ds = dense_problem();
    let (sh, p) = shard_of(&ds, "fault.mtd3");
    let opts = path_opts(ScreenerKind::Dpc, PenaltyKind::L21);
    let single = run_path_sharded(&sh, &opts).unwrap();

    let coord = Coordinator::bind("127.0.0.1:0").unwrap();
    let addr = coord.local_addr().to_string();
    let mut children = vec![spawn_worker(&addr), spawn_worker(&addr)];
    let mut fake = std::net::TcpStream::connect(&addr).unwrap();
    mtfl_dpc::serve::proto::write_frame(
        &mut fake,
        mtfl_dpc::serve::proto::ok_reply(mtfl_dpc::serve::json::Value::Null).as_bytes(),
    )
    .unwrap();
    fake.shutdown(std::net::Shutdown::Write).unwrap();

    let mut sweeps =
        DistribSweeps::connect(&sh, &p, opts.solve.penalty, &coord, 3, 60.0).unwrap();
    let mut obs = noop();
    let res = run_path_sharded_core(&sh, &opts, &mut obs, &mut sweeps, None).unwrap();
    sweeps.shutdown();
    let ledgers = sweeps.ledgers();
    drop(sweeps);
    drop(fake);
    for c in &mut children {
        let _ = c.wait();
    }
    std::fs::remove_file(&p).ok();

    assert_bitwise(&single, &res, "2 survivors + 1 dead");
    // the dead worker ends owning nothing; survivors cover every block
    let assigned: usize = ledgers.iter().map(|w| w.blocks).sum();
    assert_eq!(assigned, sh.n_blocks(), "surviving coverage");
    let idle = ledgers.iter().filter(|w| w.sweeps == 0).count();
    assert_eq!(idle, 1, "exactly the fake worker served zero sweeps: {ledgers:?}");
}

#[test]
fn resume_mid_grid_reproduces_the_path_bitwise() {
    let ds = dense_problem();
    let (sh, p) = shard_of(&ds, "ckpt.mtd3");
    let opts = path_opts(ScreenerKind::Dpc, PenaltyKind::L21);
    let dir = tmp("ckpt_dir");
    std::fs::remove_dir_all(&dir).ok();

    let cfg = CheckpointCfg { dir: dir.clone(), resume: false };
    let mut obs = noop();
    let full = run_path_sharded_checkpointed(&sh, &opts, &mut obs, Some(&cfg)).unwrap();

    // interrupt after step 3: drop every later record, resume, compare
    for step in 4..opts.ratios.len() {
        std::fs::remove_file(step_path(&dir, step)).unwrap();
    }
    let cfg = CheckpointCfg { dir: dir.clone(), resume: true };
    let mut obs = noop();
    let resumed = run_path_sharded_checkpointed(&sh, &opts, &mut obs, Some(&cfg)).unwrap();
    assert_bitwise(&full, &resumed, "resume from step 3");

    // a completed run resumes to itself (empty remaining grid)
    let mut obs = noop();
    let again = run_path_sharded_checkpointed(&sh, &opts, &mut obs, Some(&cfg)).unwrap();
    assert_bitwise(&full, &again, "resume with nothing left to do");

    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_file(&p).ok();
}

#[test]
fn corrupt_or_truncated_checkpoints_error_naming_the_flag() {
    let ds = dense_problem();
    let (sh, p) = shard_of(&ds, "ckpt_bad.mtd3");
    let opts = path_opts(ScreenerKind::Dpc, PenaltyKind::L21);
    let dir = tmp("ckpt_bad_dir");
    std::fs::remove_dir_all(&dir).ok();

    let cfg = CheckpointCfg { dir: dir.clone(), resume: false };
    let mut obs = noop();
    run_path_sharded_checkpointed(&sh, &opts, &mut obs, Some(&cfg)).unwrap();

    // flip one byte in the newest record: resume must refuse, loudly
    let newest = step_path(&dir, opts.ratios.len() - 1);
    let mut bytes = std::fs::read(&newest).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xff;
    std::fs::write(&newest, &bytes).unwrap();
    let cfg = CheckpointCfg { dir: dir.clone(), resume: true };
    let mut obs = noop();
    let err = run_path_sharded_checkpointed(&sh, &opts, &mut obs, Some(&cfg)).unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("--checkpoint"),
        "corruption error must name the flag, got: {msg}"
    );

    // truncation (a crash mid-write of a non-atomic copy) is also caught
    bytes[mid] ^= 0xff; // restore …
    bytes.truncate(bytes.len() - 5); // … then tear the tail off
    std::fs::write(&newest, &bytes).unwrap();
    let mut obs = noop();
    let err = run_path_sharded_checkpointed(&sh, &opts, &mut obs, Some(&cfg)).unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("--checkpoint"),
        "truncation error must name the flag, got: {msg}"
    );

    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_file(&p).ok();
}
