//! Safety under solver inexactness — the regression suite for the
//! gap-certified screening subsystem (DESIGN.md §9).
//!
//! The pre-fix hole: `DualRef::from_solution` treated a finite-tolerance
//! solve as the exact dual optimum, so at loose tolerance the Theorem-5
//! ball could exclude the true θ*(λ) and "safe" screening could reject an
//! active feature. These tests run the path at tol 1e-3 — far looser than
//! anything the old rule could survive — with the post-hoc verifier armed
//! for every screener kind, and re-certify the per-λ objectives against
//! independent tight solves.

use mtfl_dpc::coordinator::lambda_grid;
use mtfl_dpc::coordinator::path::{run_path, EngineKind, PathOptions, ScreenerKind};
use mtfl_dpc::data::synthetic::{synthetic1, synthetic2, SynthOptions};
use mtfl_dpc::screening::dpc::{DpcScreener, DualRef};
use mtfl_dpc::solver::{fista, SolveOptions};
use mtfl_dpc::PenaltyKind;

fn loose_opts(k: ScreenerKind, dynamic_every: usize) -> PathOptions {
    PathOptions {
        ratios: lambda_grid(10, 1.0, 0.05),
        solve: SolveOptions { tol: 1e-3, dynamic_every, ..Default::default() },
        screener: k,
        verify_safety: true,
        ..Default::default()
    }
}

/// Run a loose-tolerance path with the verifier armed, then certify every
/// third λ against an independent tight solve: a wrongly-screened path
/// converges (its restricted gap still closes) but to a strictly worse
/// objective, which this catches.
fn assert_loose_path_safe(kind: ScreenerKind, dynamic_every: usize) {
    assert_loose_path_safe_for(kind, dynamic_every, PenaltyKind::L21);
}

/// The same certification generalized over the penalty seam: the loose
/// path carries `penalty` end to end (prox, gap, screen, verifier), and
/// the independent tight reference solves the *same* penalized problem.
fn assert_loose_path_safe_for(kind: ScreenerKind, dynamic_every: usize, penalty: PenaltyKind) {
    let (ds, _) =
        synthetic1(&SynthOptions { t: 3, n: 12, d: 80, seed: 77, ..Default::default() });
    let mut opts = loose_opts(kind, dynamic_every);
    opts.solve.penalty = penalty;
    let run = run_path(&ds, &opts, &EngineKind::Exact).unwrap_or_else(|e| {
        panic!("{kind:?}/{penalty} loose path failed the safety verifier: {e}")
    });
    let tight_opts = SolveOptions { penalty, ..SolveOptions::tight() };
    for rec in run.records.iter().skip(1).step_by(3) {
        let tight = fista(&ds, rec.lam, None, &tight_opts);
        assert!(
            rec.obj <= tight.obj * (1.0 + 5e-3) + 1e-9,
            "{kind:?}/{penalty}: ratio {} objective {} stuck above the true optimum {}",
            rec.ratio,
            rec.obj,
            tight.obj
        );
    }
}

#[test]
fn loose_dpc_path_is_safe() {
    assert_loose_path_safe(ScreenerKind::Dpc, 0);
}

#[test]
fn loose_gapsafe_path_is_safe() {
    assert_loose_path_safe(ScreenerKind::GapSafe, 0);
}

#[test]
fn loose_cs_path_is_safe() {
    assert_loose_path_safe(ScreenerKind::DpcCs, 0);
}

#[test]
fn loose_oneshot_path_is_safe() {
    assert_loose_path_safe(ScreenerKind::DpcOneShot, 0);
}

#[test]
fn loose_unscreened_path_is_safe() {
    assert_loose_path_safe(ScreenerKind::None, 0);
}

#[test]
fn loose_dynamic_dpc_path_is_safe() {
    assert_loose_path_safe(ScreenerKind::Dpc, 5);
}

#[test]
fn loose_dynamic_gapsafe_path_is_safe() {
    assert_loose_path_safe(ScreenerKind::GapSafe, 5);
}

// --- penalty seam (DESIGN.md §14): the same loose-tolerance safety
// certification for the non-ℓ2,1 instances, static and dynamic ---

#[test]
fn loose_sgl_path_is_safe() {
    assert_loose_path_safe_for(ScreenerKind::GapSafe, 0, PenaltyKind::Sgl { alpha: 0.4 });
}

#[test]
fn loose_dynamic_sgl_path_is_safe() {
    assert_loose_path_safe_for(ScreenerKind::GapSafe, 5, PenaltyKind::Sgl { alpha: 0.4 });
}

#[test]
fn loose_gowl_path_is_safe() {
    assert_loose_path_safe_for(ScreenerKind::GapSafe, 0, PenaltyKind::Gowl { gamma: 1.0 });
}

#[test]
fn loose_dynamic_gowl_path_is_safe() {
    assert_loose_path_safe_for(ScreenerKind::GapSafe, 5, PenaltyKind::Gowl { gamma: 1.0 });
}

#[test]
fn degenerate_knobs_recover_the_l21_path() {
    // sgl at α = 0 and gowl at γ = 0 are the ℓ2,1 norm (numerically, not
    // bitwise — their prox/scale formulas regroup the arithmetic), so the
    // whole screened path must land on the same objectives and active sets
    let (ds, _) =
        synthetic2(&SynthOptions { t: 3, n: 12, d: 80, seed: 80, ..Default::default() });
    let l21 = run_path(&ds, &loose_opts(ScreenerKind::GapSafe, 0), &EngineKind::Exact).unwrap();
    for pk in [PenaltyKind::Sgl { alpha: 0.0 }, PenaltyKind::Gowl { gamma: 0.0 }] {
        let mut opts = loose_opts(ScreenerKind::GapSafe, 0);
        opts.solve.penalty = pk;
        let run = run_path(&ds, &opts, &EngineKind::Exact).unwrap();
        assert_eq!(run.records.len(), l21.records.len());
        for (a, b) in run.records.iter().zip(&l21.records) {
            assert!(
                (a.lam - b.lam).abs() <= 1e-9 * b.lam,
                "{pk}: λ_max drifted from ℓ2,1 at ratio {}",
                b.ratio
            );
            assert!(
                (a.obj - b.obj).abs() <= 3e-3 * b.obj.abs().max(1.0),
                "{pk}: obj mismatch at ratio {}: {} vs {}",
                a.ratio,
                a.obj,
                b.obj
            );
        }
    }
}

#[test]
fn loose_screened_paths_match_unscreened() {
    // the acceptance shape: screened vs unscreened objective parity at
    // tol 1e-3 for every screener kind (both sides carry ≤ tol·obj slack)
    let (ds, _) =
        synthetic2(&SynthOptions { t: 3, n: 12, d: 80, seed: 78, ..Default::default() });
    let baseline = run_path(&ds, &loose_opts(ScreenerKind::None, 0), &EngineKind::Exact).unwrap();
    for kind in [
        ScreenerKind::Dpc,
        ScreenerKind::GapSafe,
        ScreenerKind::DpcCs,
        ScreenerKind::DpcOneShot,
    ] {
        let run = run_path(&ds, &loose_opts(kind, 0), &EngineKind::Exact).unwrap();
        for (a, b) in run.records.iter().zip(&baseline.records) {
            assert!(
                (a.obj - b.obj).abs() <= 3e-3 * b.obj.abs().max(1.0),
                "{kind:?}: obj mismatch at ratio {}: {} vs {}",
                a.ratio,
                a.obj,
                b.obj
            );
        }
    }
}

#[test]
fn sequential_screen_from_loose_reference_keeps_active_rows() {
    // the exact pre-fix failure mode, certified row-by-row: build the
    // sequential reference from a deliberately loose solve, screen nearby
    // λ, and check every rejection against a tight solve's active set
    let (ds, _) =
        synthetic2(&SynthOptions { t: 3, n: 12, d: 100, seed: 79, ..Default::default() });
    let (_, lmax) = DualRef::at_lambda_max(&ds);
    let lam0 = 0.5 * lmax;
    let loose = SolveOptions { tol: 1e-3, check_every: 1, ..Default::default() };
    let sol0 = fista(&ds, lam0, None, &loose);
    let dref = DualRef::from_solution(&ds, lam0, &sol0.w);
    let screener = DpcScreener::new(&ds);
    for ratio_of_lam0 in [0.9999, 0.99, 0.9, 0.7] {
        let lam = ratio_of_lam0 * lam0;
        let out = screener.screen(&ds, &dref, lam);
        let tight = fista(&ds, lam, None, &SolveOptions::tight());
        let rn = tight.row_norms(ds.t());
        for (l, (&rej, &norm)) in out.rejected.iter().zip(&rn).enumerate() {
            assert!(
                !rej || norm < 1e-8,
                "UNSAFE: loose-reference screen rejected active row {l} \
                 (norm {norm}) at {ratio_of_lam0}·lam0"
            );
        }
    }
}
