//! Penalty-seam parity suite (DESIGN.md §14): routing the ℓ2,1 norm
//! through the [`mtfl_dpc::penalty::Penalty`] trait must reproduce the
//! pre-seam concrete kernels **bit for bit** — the refactor's headline
//! acceptance criterion.
//!
//! Two layers of pinning:
//!
//! * **Op level** — every `_for` function and trait method compared
//!   against the untouched concrete function *and* an inline golden
//!   transcription of the pre-refactor arithmetic (`to_bits` equality,
//!   so a regrouped sum or reordered fold cannot hide).
//! * **Path level** — full screened λ-paths with the penalty explicitly
//!   set to `PenaltyKind::L21`, bit-identical across the dense and CSC
//!   backends at executor widths 1 and 4, and matching the sharded
//!   backend to its documented tolerance.
//!
//! Width tests take the process-wide `EXCLUSIVE` lock and zero the
//! serial cutoff, exactly like `tests/executor_parallel.rs`, so the
//! small problems really exercise the pooled sweeps.

use mtfl_dpc::coordinator::lambda_grid;
use mtfl_dpc::coordinator::path::{
    run_path, run_path_sharded, EngineKind, PathOptions, PathRunResult, ScreenerKind,
};
use mtfl_dpc::data::io::save_sharded;
use mtfl_dpc::data::synthetic::{synthetic1, SynthOptions};
use mtfl_dpc::data::{Dataset, ShardedDataset};
use mtfl_dpc::linalg::{dot_f64, nrm2_f64};
use mtfl_dpc::ops;
use mtfl_dpc::penalty::{Penalty, L21};
use mtfl_dpc::screening::{ball_scores, ball_scores_for, secular};
use mtfl_dpc::solver::{fista, SolveOptions};
use mtfl_dpc::testing::scale;
use mtfl_dpc::util::executor;
use mtfl_dpc::PenaltyKind;
use std::path::PathBuf;
use std::sync::Mutex;

static EXCLUSIVE: Mutex<()> = Mutex::new(());

fn exclusive() -> std::sync::MutexGuard<'static, ()> {
    EXCLUSIVE.lock().unwrap_or_else(|e| e.into_inner())
}

/// Zero the serial cutoff for the guard's lifetime (restoring the prior
/// value) so the width-parity tests exercise the pooled sweep paths.
struct ZeroCutoff(Option<String>);

impl ZeroCutoff {
    fn set() -> Self {
        let old = std::env::var("MTFL_SERIAL_CUTOFF").ok();
        std::env::set_var("MTFL_SERIAL_CUTOFF", "0");
        ZeroCutoff(old)
    }
}

impl Drop for ZeroCutoff {
    fn drop(&mut self) {
        match self.0.take() {
            Some(v) => std::env::set_var("MTFL_SERIAL_CUTOFF", v),
            None => std::env::remove_var("MTFL_SERIAL_CUTOFF"),
        }
    }
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("mtfl_penpar_{}_{}", std::process::id(), name))
}

fn problem() -> Dataset {
    synthetic1(&SynthOptions {
        t: 3,
        n: scale::n(14),
        d: scale::d(120),
        support_frac: 0.08,
        noise: 0.05,
        seed: 87,
    })
    .0
}

fn assert_bits_eq(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: bit mismatch at {i}: {x} vs {y}");
    }
}

fn assert_stacked_bits_eq(a: &[Vec<f64>], b: &[Vec<f64>], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: task-count mismatch");
    for (t, (at, bt)) in a.iter().zip(b).enumerate() {
        assert_bits_eq(at, bt, &format!("{what} task {t}"));
    }
}

// ---------------------------------------------------------------------------
// op level: trait methods vs concrete functions vs golden transcriptions
// ---------------------------------------------------------------------------

#[test]
fn value_and_prox_match_golden_transcriptions() {
    let ds = problem();
    let t = ds.t();
    let (lmax, _, _) = ops::lambda_max(&ds);
    let w = fista(&ds, 0.4 * lmax, None, &SolveOptions::default()).w;

    // golden ‖W‖₂,₁: the pre-seam ops::l21_norm body, transcribed inline
    let golden: f64 = w.chunks_exact(t).map(nrm2_f64).sum();
    assert_eq!(L21.value(&w, t).to_bits(), golden.to_bits(), "L21 value");
    assert_eq!(PenaltyKind::L21.value(&w, t).to_bits(), golden.to_bits(), "enum value");
    assert_eq!(ops::l21_norm(&w, t).to_bits(), golden.to_bits(), "concrete value");

    // golden prox: the pre-seam row-wise group soft-threshold, transcribed
    let kappa = 0.3 * lmax;
    let mut golden_w = w.clone();
    let mut golden_alive = 0usize;
    for row in golden_w.chunks_exact_mut(t) {
        let norm = nrm2_f64(row);
        if norm <= kappa {
            row.fill(0.0);
        } else {
            let s = 1.0 - kappa / norm;
            for v in row.iter_mut() {
                *v *= s;
            }
            golden_alive += 1;
        }
    }
    for pen in [&L21 as &dyn Penalty, &PenaltyKind::L21] {
        let mut via_trait = w.clone();
        let alive = pen.prox_inplace(&mut via_trait, t, kappa);
        assert_eq!(alive, golden_alive, "{} prox active count", pen.name());
        assert_bits_eq(&via_trait, &golden_w, &format!("{} prox output", pen.name()));
    }
}

#[test]
fn screening_ops_match_golden_transcriptions() {
    let ds = problem();
    let t = ds.t();
    let corr = ops::task_corr(&ds, &ops::y64(&ds));

    // golden g_l = Σ_t c_{l,t}² per row (the pre-seam gscore)
    let golden_g: Vec<f64> = corr.chunks_exact(t).map(|row| dot_f64(row, row)).collect();
    assert_bits_eq(&L21.dual_constraints(&corr, t), &golden_g, "dual_constraints");
    assert_bits_eq(&PenaltyKind::L21.dual_constraints(&corr, t), &golden_g, "enum g_l");

    // golden λ_max: first-strict-maximum fold + √max(g, 0)
    let (golden_lstar, golden_gmax) = golden_g
        .iter()
        .enumerate()
        .fold((0usize, f64::MIN), |acc, (i, &v)| if v > acc.1 { (i, v) } else { acc });
    let (s, lstar) = L21.infeasibility(&corr, t);
    assert_eq!(s.to_bits(), golden_gmax.max(0.0).sqrt().to_bits(), "infeasibility scale");
    assert_eq!(lstar, golden_lstar, "infeasibility witness");

    // and the concrete Theorem-1 entry point agrees with the seam's
    let (lmax, lstar_ref, _) = ops::lambda_max(&ds);
    let (lmax_for, lstar_for) = ops::lambda_max_for(&ds, &L21);
    assert_eq!(lmax_for.to_bits(), lmax.to_bits(), "lambda_max_for");
    assert_eq!(lstar_for, lstar_ref, "lambda_max_for witness");
    assert_eq!(s.to_bits(), lmax.to_bits(), "infeasibility(c(y)) IS lambda_max");
}

#[test]
fn ball_scores_match_golden_qp1qc_sweep_at_both_widths() {
    let _x = exclusive();
    let _z = ZeroCutoff::set();
    let ds = problem();
    let t = ds.t();
    let b2 = ds.col_sqnorms();
    let (lmax, _, _) = ops::lambda_max(&ds);
    let o = ops::stacked_scale(&ops::y64(&ds), 1.0 / lmax);
    let delta = 0.13;

    // golden Theorem-7 sweep: the per-feature secular solve over the full
    // correlation buffer, exactly what the pre-seam chunk body ran
    let corr = ops::task_corr(&ds, &o);
    let golden: Vec<f64> = (0..ds.d)
        .map(|l| {
            let a = &corr[l * t..(l + 1) * t];
            let b2l = &b2[l * t..(l + 1) * t];
            secular::qp1qc_max(a, b2l, delta).s
        })
        .collect();

    for cap in [1usize, 4] {
        let via_seam = executor::with_worker_cap(cap, || {
            ball_scores_for(&ds, &b2, &o, delta, &L21)
        });
        let via_alias =
            executor::with_worker_cap(cap, || ball_scores(&ds, &b2, &o, delta));
        assert_bits_eq(&via_seam, &golden, &format!("ball_scores_for width {cap}"));
        assert_bits_eq(&via_alias, &golden, &format!("ball_scores width {cap}"));
    }
}

#[test]
fn gap_machinery_matches_concrete_functions() {
    let ds = problem();
    let (lmax, _, _) = ops::lambda_max(&ds);
    let lam = 0.35 * lmax;
    // a deliberately loose iterate, so the dual projection actually scales
    let rough = fista(&ds, lam, None, &SolveOptions { tol: 1e-2, ..Default::default() });

    assert_eq!(
        ops::primal_obj(&ds, &rough.w, lam).to_bits(),
        ops::primal_obj_for(&ds, &rough.w, lam, &L21).to_bits(),
        "primal objective"
    );

    let (obj_a, gap_a, theta_a) = ops::duality_gap(&ds, &rough.w, lam);
    let (obj_b, gap_b, theta_b) = ops::duality_gap_for(&ds, &rough.w, lam, &PenaltyKind::L21);
    assert_eq!(obj_a.to_bits(), obj_b.to_bits(), "gap obj");
    assert_eq!(gap_a.to_bits(), gap_b.to_bits(), "gap value");
    assert_stacked_bits_eq(&theta_a, &theta_b, "gap theta");

    let z = ops::stacked_scale(&ops::residual(&ds, &rough.w), -1.0 / lam);
    let (theta_c, scale_c) = ops::dual_feasible(&ds, z.clone());
    let (theta_d, scale_d) = ops::dual_feasible_for(&ds, z, &L21);
    assert_eq!(scale_c.to_bits(), scale_d.to_bits(), "dual projection scale");
    assert_stacked_bits_eq(&theta_c, &theta_d, "projected dual point");
}

// ---------------------------------------------------------------------------
// path level: L2,1 via the trait, bit-stable across backends and widths
// ---------------------------------------------------------------------------

fn trait_path_opts(screener: ScreenerKind) -> PathOptions {
    let mut opts = PathOptions {
        ratios: lambda_grid(scale::grid(10), 1.0, 0.05),
        solve: SolveOptions { tol: 1e-7, dynamic_every: 7, ..Default::default() },
        screener,
        ..Default::default()
    };
    // explicit, not defaulted: this is the trait-routed spelling the CLI's
    // `--penalty l21` produces
    opts.solve.penalty = PenaltyKind::L21;
    opts
}

fn assert_runs_identical(a: &PathRunResult, b: &PathRunResult, what: &str) {
    assert_bits_eq(&a.last_w, &b.last_w, &format!("{what}: last_w"));
    assert_eq!(a.lam_max.to_bits(), b.lam_max.to_bits(), "{what}: lam_max");
    assert_eq!(a.records.len(), b.records.len());
    for (s, p) in a.records.iter().zip(&b.records) {
        let at = format!("{what} at ratio {}", s.ratio);
        assert_eq!(s.kept, p.kept, "{at}: kept");
        assert_eq!(s.rejected, p.rejected, "{at}: rejected");
        assert_eq!(s.col_ops, p.col_ops, "{at}: col_ops");
        assert_eq!(s.solver_iters, p.solver_iters, "{at}: iters");
        assert_eq!(s.obj.to_bits(), p.obj.to_bits(), "{at}: obj");
        assert_eq!(s.gap.to_bits(), p.gap.to_bits(), "{at}: gap");
    }
}

#[test]
fn l21_trait_path_bit_identical_across_widths_on_both_backends() {
    let _x = exclusive();
    let _z = ZeroCutoff::set();
    let dense = problem();
    let csc = dense.to_csc();
    // DPC exercises the ℓ2,1-specialized geometry the seam must keep
    // intact; GapSafe exercises the penalty-generic screen/verify route.
    // Width parity is bitwise per backend; across backends the kernels
    // accumulate in different orders (see tests/sparse_backend.rs), so
    // dense vs CSC pins keep-sets exactly and trajectories to rounding.
    for screener in [ScreenerKind::Dpc, ScreenerKind::GapSafe] {
        let opts = trait_path_opts(screener);
        let mut per_backend: Vec<PathRunResult> = Vec::new();
        for (tag, ds) in [("dense", &dense), ("csc", &csc)] {
            let serial = executor::with_worker_cap(1, || {
                run_path(ds, &opts, &EngineKind::Exact).unwrap()
            });
            let pooled = executor::with_worker_cap(4, || {
                run_path(ds, &opts, &EngineKind::Exact).unwrap()
            });
            assert_runs_identical(&serial, &pooled, &format!("{screener:?}/{tag}"));
            per_backend.push(serial);
        }
        let (d, c) = (&per_backend[0], &per_backend[1]);
        assert_eq!(d.records.len(), c.records.len());
        for (a, b) in d.records.iter().zip(&c.records) {
            let at = format!("{screener:?} dense vs csc at ratio {}", a.ratio);
            assert_eq!(a.kept, b.kept, "{at}: kept");
            assert_eq!(a.rejected, b.rejected, "{at}: rejected");
            assert!(
                (a.obj - b.obj).abs() <= 1e-7 * b.obj.abs().max(1.0),
                "{at}: obj {} vs {}",
                a.obj,
                b.obj
            );
        }
        let dmax = d
            .last_w
            .iter()
            .zip(&c.last_w)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f64, f64::max);
        assert!(dmax < 1e-6, "{screener:?}: final W diverges across backends by {dmax}");
    }
}

#[test]
fn l21_trait_path_matches_sharded_backend() {
    let _x = exclusive();
    let _z = ZeroCutoff::set();
    let ds = problem();
    let p = tmp("trait_parity.mtd3");
    save_sharded(&ds, &p, scale::n(14) * 3 * 4 * 8).unwrap();
    let sh = ShardedDataset::open(&p).unwrap();
    let opts = trait_path_opts(ScreenerKind::Dpc);
    let dense = run_path(&ds, &opts, &EngineKind::Exact).unwrap();
    let sharded = run_path_sharded(&sh, &opts).unwrap();
    std::fs::remove_file(&p).ok();

    // keep-sets exact; solutions to the documented out-of-core tolerance
    assert_eq!(dense.records.len(), sharded.path.records.len());
    for (a, b) in dense.records.iter().zip(&sharded.path.records) {
        assert_eq!(a.kept, b.kept, "kept mismatch at ratio {}", a.ratio);
        assert_eq!(a.rejected, b.rejected, "rejected mismatch at ratio {}", a.ratio);
        assert!(
            (a.obj - b.obj).abs() <= 1e-9 * a.obj.abs().max(1.0),
            "objective mismatch at ratio {}: {} vs {}",
            a.ratio,
            a.obj,
            b.obj
        );
    }
    let dmax = dense
        .last_w
        .iter()
        .zip(&sharded.path.last_w)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f64, f64::max);
    assert!(dmax < 1e-7, "final W mismatch {dmax}");
}
