//! Integration tests for the one-pass λ-path grid workflows (CV and
//! stability selection) built on the [`PathObserver`] streaming API:
//!
//! * CV pays for each fold's screened path exactly once (col-ops parity
//!   with a direct per-fold `run_path`), and honors the configured
//!   screener/solver instead of hardcoding DPC + FISTA;
//! * stability selection accumulates the true union-over-λ active mask,
//!   catching features that are active only at large λ — the old
//!   implementation tested only the final (smallest-λ) solution.

use mtfl_dpc::coordinator::cv::{cross_validate, kfold_splits, validation_mse};
use mtfl_dpc::coordinator::lambda_grid;
use mtfl_dpc::coordinator::path::{run_path, EngineKind, PathOptions, ScreenerKind, SolverKind};
use mtfl_dpc::coordinator::stability::stability_selection;
use mtfl_dpc::data::synthetic::{synthetic1, SynthOptions};
use mtfl_dpc::data::Dataset;
use mtfl_dpc::ops;
use mtfl_dpc::solver::{bcd, SolveOptions};

fn cv_dataset() -> Dataset {
    synthetic1(&SynthOptions { t: 3, n: 30, d: 40, support_frac: 0.1, noise: 0.3, seed: 71 }).0
}

#[test]
fn cv_runs_each_fold_path_exactly_once() {
    // the one-pass acceptance gate: total solver column-sweep work of
    // cross_validate must equal the cost of running each fold's screened
    // path once — the pre-observer implementation re-walked the whole path
    // a second time per fold to recover per-λ solutions (~2× the work)
    let ds = cv_dataset();
    let opts = PathOptions {
        ratios: lambda_grid(8, 1.0, 0.02),
        solve: SolveOptions { tol: 1e-7, ..Default::default() },
        screener: ScreenerKind::Dpc,
        ..Default::default()
    };
    let direct: usize = kfold_splits(&ds, 3, 0)
        .unwrap()
        .iter()
        .map(|(train, _)| run_path(train, &opts, &EngineKind::Exact).unwrap().total_col_ops())
        .sum();
    let cv = cross_validate(&ds, &opts, 3, 0).unwrap();
    assert!(direct > 0, "premise: the folds did solver work");
    assert_eq!(
        cv.col_ops, direct,
        "CV fold cost must be one screened path per fold, not {} vs direct {}",
        cv.col_ops, direct
    );
}

#[test]
fn cv_respects_configured_screener_and_solver() {
    // regression: cross_validate used to hardcode DpcScreener + fista for
    // the per-λ scoring walk, silently ignoring opts. A GapSafe + BCD CV
    // must agree with an independent per-λ reference (warm-started BCD
    // solves on each training split, no screening at all).
    let ds = cv_dataset();
    let ratios = lambda_grid(6, 1.0, 0.05);
    let k = 3;

    let splits = kfold_splits(&ds, k, 0).unwrap();
    let mut ref_mse = vec![0.0f64; ratios.len()];
    for (train, val) in &splits {
        let (lam_max, _, _) = ops::lambda_max(train);
        let mut w_prev: Option<Vec<f64>> = None;
        for (i, &ratio) in ratios.iter().enumerate() {
            let lam = ratio * lam_max;
            let sol = bcd(train, lam, w_prev.as_deref(), &SolveOptions::tight());
            ref_mse[i] += validation_mse(val, &sol.w) / k as f64;
            w_prev = Some(sol.w);
        }
    }

    let opts = PathOptions {
        ratios: ratios.clone(),
        solve: SolveOptions { tol: 1e-9, ..Default::default() },
        screener: ScreenerKind::GapSafe,
        solver: SolverKind::Bcd,
        ..Default::default()
    };
    let cv = cross_validate(&ds, &opts, k, 0).unwrap();
    assert_eq!(cv.mse.len(), ratios.len());
    for (i, (got, want)) in cv.mse.iter().zip(&ref_mse).enumerate() {
        assert!(
            (got - want).abs() <= 1e-5 * want.max(1.0),
            "GapSafe+BCD CV diverged from the reference at grid index {i}: {got} vs {want}"
        );
    }
}

#[test]
fn stability_selects_features_active_only_at_large_lambda() {
    // the grid deliberately ends back up at a near-λ_max point: the final
    // solution of each subsample path is (almost) empty, so the old
    // last-λ mask selects (almost) nothing, while the documented
    // "nonzero at *any* λ" semantics must still surface the true support
    // that is active at the interior λ = 0.1·λ_max point
    let (ds, gt) =
        synthetic1(&SynthOptions { t: 3, n: 30, d: 40, support_frac: 0.1, noise: 0.1, seed: 21 });
    let opts = PathOptions {
        ratios: vec![1.0, 0.1, 0.98],
        solve: SolveOptions { tol: 1e-8, ..Default::default() },
        // no screening: the sequential DPC rule assumes a descending grid
        screener: ScreenerKind::None,
        ..Default::default()
    };

    // premise (old-semantics proxy): at the final grid point the full-data
    // solution keeps at most the single strongest feature
    let run = run_path(&ds, &opts, &EngineKind::Exact).unwrap();
    let t = ds.t();
    let last_active: Vec<usize> = run
        .last_w
        .chunks_exact(t)
        .enumerate()
        .filter_map(|(l, row)| ops::row_is_active(row, 1e-8).then_some(l))
        .collect();
    assert!(
        last_active.len() <= 1,
        "premise: the ratio-0.98 solution should be near-empty, got {last_active:?}"
    );

    let res = stability_selection(&ds, &opts, 4, 0.75, 0).unwrap();
    let stable_true: Vec<usize> =
        gt.active.iter().copied().filter(|l| res.stable.contains(l)).collect();
    assert!(
        stable_true.len() >= 2,
        "union-over-λ mask must recover the support active at λ=0.1·λ_max: \
         stable {:?} vs truth {:?}",
        res.stable,
        gt.active
    );
    let missed_by_last_mask = stable_true.iter().filter(|l| !last_active.contains(l)).count();
    assert!(
        missed_by_last_mask >= 1,
        "test premise broken: the last-λ mask already contains every stable feature"
    );
}
