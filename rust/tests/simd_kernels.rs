//! Kernel-layer acceptance suite (DESIGN.md §12):
//!
//! * **Backend bit-equality** — every dispatching kernel (dense dots,
//!   sparse dots, axpy, scale_add) returns exactly the bits of the scalar
//!   reference implementation, across tail lengths that hit every branch
//!   of the accumulation contract (empty, sub-lane, one chunk ± 1, one
//!   block ± 1, multi-block) and random data.
//! * **Blocking is the contract** — the cache-blocked panel sweeps in
//!   `ops` (`task_corr`, `forward`) reproduce the plain per-column
//!   kernels bit for bit, on both matrix backends.
//! * **End-to-end pinning** — a full λ-path run is bit-identical with the
//!   dispatcher pinned to scalar vs. free to use SIMD, so the PR 1/5
//!   parity and determinism suites keep holding with the `simd` feature
//!   on or off.
//!
//! Tests that flip the process-global [`simd::force_scalar`] switch hold
//! `BACKEND` for their whole body so the pin cannot leak mid-test.

use mtfl_dpc::coordinator::lambda_grid;
use mtfl_dpc::coordinator::path::{run_path, EngineKind, PathOptions, ScreenerKind};
use mtfl_dpc::data::synthetic::{synthetic1, SynthOptions};
use mtfl_dpc::data::Dataset;
use mtfl_dpc::linalg::simd;
use mtfl_dpc::ops;
use mtfl_dpc::solver::SolveOptions;
use mtfl_dpc::testing::scale;
use mtfl_dpc::util::Pcg64;
use std::sync::Mutex;

static BACKEND: Mutex<()> = Mutex::new(());

fn backend_lock() -> std::sync::MutexGuard<'static, ()> {
    BACKEND.lock().unwrap_or_else(|e| e.into_inner())
}

/// Pin the dispatcher to scalar for the guard's lifetime.
struct ForceScalar;

impl ForceScalar {
    fn pin() -> Self {
        simd::force_scalar(true);
        ForceScalar
    }
}

impl Drop for ForceScalar {
    fn drop(&mut self) {
        simd::force_scalar(false);
    }
}

/// Every length class the contract branches on: empty, below one lane
/// chunk, exactly one chunk, chunk ± 1, a few chunks with tails, exactly
/// one block, block ± 1, and a multi-block size with a ragged tail.
const LENS_FULL: &[usize] = &[
    0,
    1,
    2,
    3,
    4,
    5,
    6,
    7,
    8,
    9,
    10,
    11,
    12,
    13,
    14,
    15,
    16,
    17,
    31,
    33,
    simd::ACC_BLOCK,
    simd::ACC_BLOCK + 1,
    2 * simd::ACC_BLOCK - 1,
];

/// Interpreter-speed subset (Miri/loom legs): one representative of each
/// branch class — empty, sub-lane, exact lane chunk, ragged tail, exact
/// block, and block + ragged tail — so the contract's every path still
/// executes without the full sweep.
const LENS_SHRUNK: &[usize] =
    &[0, 1, 7, 8, 13, simd::ACC_BLOCK, simd::ACC_BLOCK + 13];

fn lens() -> &'static [usize] {
    if scale::shrunk() {
        LENS_SHRUNK
    } else {
        LENS_FULL
    }
}

fn rand_f32(rng: &mut Pcg64, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32).collect()
}

fn rand_f64(rng: &mut Pcg64, n: usize) -> Vec<f64> {
    (0..n).map(|_| rng.normal()).collect()
}

fn assert_vec_bits_eq(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: bit mismatch at {i}: {x} vs {y}");
    }
}

#[test]
fn dense_dots_dispatch_equals_scalar_bitwise() {
    for (li, &n) in lens().iter().enumerate() {
        let mut rng = Pcg64::with_stream(0xd07, li as u64);
        let af = rand_f32(&mut rng, n);
        let bf = rand_f32(&mut rng, n);
        let ad = rand_f64(&mut rng, n);
        let bd = rand_f64(&mut rng, n);
        let cases = [
            ("dot_mixed", simd::dot_mixed(&af, &bd), simd::scalar::dot_mixed(&af, &bd)),
            ("dot_f32_f64", simd::dot_f32_f64(&af, &bf), simd::scalar::dot_f32_f64(&af, &bf)),
            ("dot_f64", simd::dot_f64(&ad, &bd), simd::scalar::dot_f64(&ad, &bd)),
        ];
        for (name, got, want) in cases {
            assert_eq!(
                got.to_bits(),
                want.to_bits(),
                "{name} n={n} [{}]: dispatch {got} != scalar {want}",
                simd::active_backend()
            );
        }
    }
}

#[test]
fn dense_dots_match_naive_values() {
    // the contract reassociates; the *value* must still be the same sum
    // to normal rounding error
    let mut rng = Pcg64::with_stream(0xacc, 1);
    let n = scale::kernel_len(4999);
    let a = rand_f32(&mut rng, n);
    let b = rand_f64(&mut rng, n);
    let naive: f64 = a.iter().zip(&b).map(|(&x, &y)| x as f64 * y).sum();
    let got = simd::dot_mixed(&a, &b);
    assert!((got - naive).abs() <= 1e-9 * naive.abs().max(1.0), "{got} vs naive {naive}");
    let ad = rand_f64(&mut rng, n);
    let naive2: f64 = ad.iter().map(|&x| x * x).sum();
    let got2 = mtfl_dpc::linalg::nrm2_f64(&ad);
    assert!((got2 * got2 - naive2).abs() <= 1e-9 * naive2.max(1.0));
}

#[test]
fn sparse_dots_dispatch_equals_scalar_bitwise() {
    let vlen = 6000usize;
    for (li, &k) in lens().iter().enumerate() {
        let mut rng = Pcg64::with_stream(0x59a5, li as u64);
        // k distinct, strictly increasing row indices in [0, vlen)
        let indices: Vec<u32> = (0..k).map(|i| (i * vlen / k.max(1)) as u32).collect();
        let values = rand_f32(&mut rng, k);
        let v64 = rand_f64(&mut rng, vlen);
        let v32 = rand_f32(&mut rng, vlen);
        let gm = simd::sp_dot_mixed(&indices, &values, &v64);
        let wm = simd::scalar::sp_dot_mixed(&indices, &values, &v64);
        assert_eq!(gm.to_bits(), wm.to_bits(), "sp_dot_mixed k={k}: {gm} vs {wm}");
        let gf = simd::sp_dot_f32_f64(&indices, &values, &v32);
        let wf = simd::scalar::sp_dot_f32_f64(&indices, &values, &v32);
        assert_eq!(gf.to_bits(), wf.to_bits(), "sp_dot_f32_f64 k={k}: {gf} vs {wf}");
        let mut ya = rand_f64(&mut rng, vlen);
        let mut yb = ya.clone();
        simd::sp_axpy_f64(0.75, &indices, &values, &mut ya);
        simd::scalar::sp_axpy_f64(0.75, &indices, &values, &mut yb);
        assert_vec_bits_eq(&ya, &yb, &format!("sp_axpy_f64 k={k}"));
    }
}

#[test]
fn elementwise_kernels_dispatch_equals_scalar_bitwise() {
    for (li, &n) in lens().iter().enumerate() {
        let mut rng = Pcg64::with_stream(0xe1e, li as u64);
        let x = rand_f32(&mut rng, n);
        let a = rand_f64(&mut rng, n);
        let b = rand_f64(&mut rng, n);
        let mut ya = rand_f64(&mut rng, n);
        let mut yb = ya.clone();
        simd::axpy_f64(-1.25, &x, &mut ya);
        simd::scalar::axpy_f64(-1.25, &x, &mut yb);
        assert_vec_bits_eq(&ya, &yb, &format!("axpy_f64 n={n}"));
        let mut oa = vec![0.0f64; n];
        let mut ob = vec![0.0f64; n];
        simd::scale_add(&a, 0.375, &b, &mut oa);
        simd::scalar::scale_add(&a, 0.375, &b, &mut ob);
        assert_vec_bits_eq(&oa, &ob, &format!("scale_add n={n}"));
    }
}

#[test]
fn axpy_alpha_zero_preserves_negative_zero() {
    // alpha == 0 must be a no-op: adding ±0.0 would flip -0.0 to +0.0
    let x = vec![1.0f32; 9];
    let mut y = vec![-0.0f64; 9];
    simd::axpy_f64(0.0, &x, &mut y);
    for (i, v) in y.iter().enumerate() {
        assert_eq!(v.to_bits(), (-0.0f64).to_bits(), "axpy(0.0) disturbed y[{i}]");
    }
}

/// A multi-block problem (n > ACC_BLOCK) so the panel sweeps really cross
/// block boundaries.
fn tall_problem() -> Dataset {
    synthetic1(&SynthOptions {
        t: 2,
        n: simd::ACC_BLOCK + 52,
        d: 6,
        ..Default::default()
    })
    .0
}

#[test]
fn blocked_task_corr_equals_per_column_dots_bitwise() {
    for ds in [tall_problem(), tall_problem().to_csc()] {
        let v = ops::y64(&ds);
        let corr = ops::task_corr(&ds, &v);
        for ti in 0..ds.t() {
            for l in 0..ds.d {
                let want = ds.col(ti, l).dot_mixed(&v[ti]);
                let got = corr[l * ds.t() + ti];
                assert_eq!(
                    got.to_bits(),
                    want.to_bits(),
                    "task_corr[{l},{ti}] {got} != plain dot {want}"
                );
            }
        }
    }
}

#[test]
fn blocked_forward_equals_per_column_axpy_bitwise() {
    let ds = tall_problem();
    let t = ds.t();
    let mut rng = Pcg64::with_stream(0xf0d, 3);
    let w: Vec<f64> =
        (0..ds.d * t).map(|i| if i % 3 == 0 { 0.0 } else { rng.normal() }).collect();
    let z = ops::forward(&ds, &w);
    for ti in 0..t {
        let mut zn = vec![0.0f64; ds.tasks[ti].n];
        for l in 0..ds.d {
            let wl = w[l * t + ti];
            if wl != 0.0 {
                let col = ds.col(ti, l).to_vec();
                simd::axpy_f64(wl, &col, &mut zn);
            }
        }
        assert_vec_bits_eq(&z[ti], &zn, &format!("forward task {ti}"));
    }
}

#[test]
fn col_sqnorms_bit_stable_under_backend_pin() {
    let _g = backend_lock();
    let ds = tall_problem();
    let free = ds.col_sqnorms();
    let pinned = {
        let _p = ForceScalar::pin();
        assert_eq!(simd::active_backend(), "scalar");
        ds.col_sqnorms()
    };
    assert_vec_bits_eq(&free, &pinned, "col_sqnorms");
}

#[test]
fn full_path_bit_identical_scalar_vs_simd_dispatch() {
    let _g = backend_lock();
    let ds = synthetic1(&SynthOptions {
        t: 3,
        n: scale::n(14),
        d: scale::d(120),
        support_frac: 0.08,
        noise: 0.05,
        seed: 61,
    })
    .0;
    let opts = PathOptions {
        ratios: lambda_grid(scale::grid(8), 1.0, 0.05),
        solve: SolveOptions { tol: 1e-7, dynamic_every: 7, ..Default::default() },
        screener: ScreenerKind::Dpc,
        ..Default::default()
    };
    let free = run_path(&ds, &opts, &EngineKind::Exact).unwrap();
    let pinned = {
        let _p = ForceScalar::pin();
        run_path(&ds, &opts, &EngineKind::Exact).unwrap()
    };
    assert_eq!(free.lam_max.to_bits(), pinned.lam_max.to_bits(), "lam_max");
    assert_vec_bits_eq(&free.last_w, &pinned.last_w, "last_w");
    assert_eq!(free.records.len(), pinned.records.len());
    for (a, b) in free.records.iter().zip(&pinned.records) {
        let at = format!("ratio {}", a.ratio);
        assert_eq!(a.kept, b.kept, "{at}: kept");
        assert_eq!(a.rejected, b.rejected, "{at}: rejected");
        assert_eq!(a.solver_iters, b.solver_iters, "{at}: iters");
        assert_eq!(a.col_ops, b.col_ops, "{at}: col_ops");
        assert_eq!(a.obj.to_bits(), b.obj.to_bits(), "{at}: obj");
        assert_eq!(a.gap.to_bits(), b.gap.to_bits(), "{at}: gap");
    }
    // sanity: the grid actually screened and solved nontrivially (the
    // shrunk Miri/loom sizes are too small to guarantee both at once)
    if !scale::shrunk() {
        assert!(free.records.iter().any(|r| r.rejected > 0 && r.kept > 0));
    }
}
