//! End-to-end exact-engine integration: screened λ-paths must reproduce
//! unscreened paths exactly (within solver tolerance) across workload
//! generators, and the screening must be safe at every step.

use mtfl_dpc::coordinator::path::{run_path, EngineKind, PathOptions, ScreenerKind, SolverKind};
use mtfl_dpc::coordinator::lambda_grid;
use mtfl_dpc::data::imagesim::{imagesim, ImageSimOptions};
use mtfl_dpc::data::snpsim::{snpsim, SnpSimOptions};
use mtfl_dpc::data::synthetic::{synthetic1, synthetic2, SynthOptions};
use mtfl_dpc::data::textsim::{textsim, TextSimOptions};
use mtfl_dpc::data::Dataset;
use mtfl_dpc::solver::SolveOptions;

fn opts(k: ScreenerKind, grid: usize) -> PathOptions {
    PathOptions {
        ratios: lambda_grid(grid, 1.0, 0.05),
        solve: SolveOptions { tol: 1e-7, ..Default::default() },
        screener: k,
        verify_safety: true,
        ..Default::default()
    }
}

fn check_equivalence(ds: &Dataset, grid: usize) {
    let screened = run_path(ds, &opts(ScreenerKind::Dpc, grid), &EngineKind::Exact).unwrap();
    let baseline = run_path(ds, &opts(ScreenerKind::None, grid), &EngineKind::Exact).unwrap();
    for (a, b) in screened.records.iter().zip(&baseline.records) {
        assert!(
            (a.obj - b.obj).abs() <= 1e-5 * b.obj.abs().max(1.0),
            "{}: obj mismatch at ratio {:.3}: {} vs {}",
            ds.name,
            a.ratio,
            a.obj,
            b.obj
        );
    }
    let dmax = screened
        .last_w
        .iter()
        .zip(&baseline.last_w)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f64, f64::max);
    assert!(dmax < 5e-4, "{}: final W mismatch {dmax}", ds.name);
    // sanity: screening actually did something on these problems
    assert!(screened.mean_rejection_ratio() > 0.3, "{}: weak screening", ds.name);
}

#[test]
fn synthetic1_path_equivalence() {
    let (ds, _) = synthetic1(&SynthOptions { t: 4, n: 14, d: 60, seed: 1, ..Default::default() });
    check_equivalence(&ds, 10);
}

#[test]
fn synthetic2_path_equivalence() {
    let (ds, _) = synthetic2(&SynthOptions { t: 4, n: 14, d: 60, seed: 2, ..Default::default() });
    check_equivalence(&ds, 10);
}

#[test]
fn textsim_path_equivalence() {
    let ds = textsim(&TextSimOptions {
        categories: 2,
        n_pos: 6,
        d: 80,
        doc_len: 60,
        ..Default::default()
    });
    check_equivalence(&ds, 6);
}

#[test]
fn imagesim_path_equivalence() {
    let ds = imagesim(&ImageSimOptions {
        classes: 3,
        n_pos: 7,
        blocks: vec![24, 40, 16],
        rank: 3,
        seed: 3,
    });
    check_equivalence(&ds, 8);
}

#[test]
fn snpsim_path_equivalence() {
    let (ds, _) = snpsim(&SnpSimOptions {
        tasks: 3,
        n: 14,
        d: 150,
        causal: 8,
        ld_block: 10,
        ld_rho: 0.6,
        noise: 0.2,
        seed: 4,
        ..Default::default()
    });
    check_equivalence(&ds, 8);
}

#[test]
fn bcd_engine_full_path() {
    let (ds, _) = synthetic1(&SynthOptions { t: 3, n: 10, d: 60, seed: 5, ..Default::default() });
    let mut o = opts(ScreenerKind::Dpc, 8);
    o.solver = SolverKind::Bcd;
    let bcd_run = run_path(&ds, &o, &EngineKind::Exact).unwrap();
    let fista_run = run_path(&ds, &opts(ScreenerKind::Dpc, 8), &EngineKind::Exact).unwrap();
    for (a, b) in bcd_run.records.iter().zip(&fista_run.records) {
        assert!((a.obj - b.obj).abs() <= 1e-4 * b.obj.abs().max(1.0));
    }
}

#[test]
fn rejection_grows_with_dimension() {
    // the paper's headline trend: higher d => higher rejection ratio
    let mean_rej = |d: usize| {
        let (ds, _) =
            synthetic1(&SynthOptions { t: 3, n: 12, d, seed: 6, ..Default::default() });
        run_path(&ds, &opts(ScreenerKind::Dpc, 8), &EngineKind::Exact)
            .unwrap()
            .mean_rejection_ratio()
    };
    let lo = mean_rej(60);
    let hi = mean_rej(400);
    assert!(
        hi >= lo - 0.02,
        "rejection should not degrade with dimension: d=60 {lo:.3} vs d=600 {hi:.3}"
    );
    assert!(hi > 0.5, "high-dim rejection should be strong, got {hi:.3}");
}

#[test]
fn grid_at_exactly_lambda_max_keeps_nothing() {
    let (ds, _) = synthetic1(&SynthOptions { t: 3, n: 10, d: 40, seed: 7, ..Default::default() });
    let res = run_path(&ds, &opts(ScreenerKind::Dpc, 6), &EngineKind::Exact).unwrap();
    let first = &res.records[0];
    assert!((first.ratio - 1.0).abs() < 1e-12);
    assert_eq!(first.kept, 0, "Theorem 1: everything screened at lambda_max");
    assert_eq!(first.inactive, ds.d);
}

#[test]
fn screening_time_is_small_fraction() {
    let (ds, _) =
        synthetic1(&SynthOptions { t: 4, n: 20, d: 400, seed: 8, ..Default::default() });
    let res = run_path(&ds, &opts(ScreenerKind::Dpc, 10), &EngineKind::Exact).unwrap();
    assert!(
        res.screen_secs < 0.5 * res.total_secs,
        "screening {}s dominates total {}s",
        res.screen_secs,
        res.total_secs
    );
}
