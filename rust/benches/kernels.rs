//! Micro-benchmarks of the hot paths on both engines (EXPERIMENTS.md §Perf
//! feeds from this target):
//!
//!   * the correlation sweep `task_corr` (the dominant cost of DPC);
//!   * the kernel layer scalar-vs-SIMD, per kernel and end-to-end, plus
//!     panel-blocked vs per-column sweeps (recorded in
//!     `BENCH_kernels.json` at the repo root, DESIGN.md §12);
//!   * the per-feature QP1QC secular solve;
//!   * full DPC screen at one λ;
//!   * the DPC score sweep on CSC vs dense storage at 1% / 5% density
//!     (results recorded in `BENCH_sparse.json` at the repo root);
//!   * static-DPC vs gap-dynamic screening on the synthetic2 path:
//!     epochs-to-converge and total column-sweep work (recorded in
//!     `BENCH_gap.json` at the repo root);
//!   * the penalty seam (DESIGN.md §14): concrete ℓ2,1 kernels vs the
//!     same operations routed through `PenaltyKind` dispatch, plus the
//!     absolute cost of the sgl/gowl prox kernels (recorded in
//!     `BENCH_penalty.json` at the repo root);
//!   * one FISTA iteration (exact) / one FISTA chunk step (AOT);
//!   * the AOT screen artifact (PJRT end-to-end including marshalling).
//!
//!     cargo bench --bench kernels

use mtfl_dpc::bench::Bencher;
use mtfl_dpc::data::synthetic::{synthetic1, SynthOptions};
use mtfl_dpc::data::{Dataset, Task};
use mtfl_dpc::linalg::{simd, CscMatrix};
use mtfl_dpc::ops;
use mtfl_dpc::penalty::{Penalty, PenaltyKind};
use mtfl_dpc::runtime::AotEngine;
use mtfl_dpc::screening::dpc::{ball, DpcScreener, DualRef};
use mtfl_dpc::screening::secular::qp1qc_max;
use mtfl_dpc::util::Pcg64;
use std::path::PathBuf;

/// Time `f` with the dispatcher pinned to scalar, then free (SIMD where
/// detected); print the speedup and return one JSON results row. The two
/// runs return bit-identical results (rust/tests/simd_kernels.rs), so the
/// ratio is pure kernel throughput.
fn bench_backends<R>(b: &Bencher, name: &str, mut f: impl FnMut() -> R) -> String {
    simd::force_scalar(true);
    let s = b.run(&format!("{name:<38} [scalar]"), &mut f);
    simd::force_scalar(false);
    let v = b.run(&format!("{name:<38} [{}]", simd::active_backend()), &mut f);
    let speedup = s.median() / v.median();
    println!("   -> {name}: {speedup:.2}x vs scalar\n");
    format!(
        "    {{\"name\": \"{name}\", \"scalar_median_s\": {:.6e}, \
         \"simd_median_s\": {:.6e}, \"speedup\": {:.2}}}",
        s.median(),
        v.median(),
        speedup
    )
}

/// Random CSC dataset at a target density (rows per column chosen
/// uniformly, Gaussian values) — the text/genomics shape of DESIGN.md §6.
fn sparse_dataset(t: usize, n: usize, d: usize, density: f64, seed: u64) -> Dataset {
    let mut root = Pcg64::new(seed);
    let k = ((density * n as f64).round() as usize).clamp(1, n);
    let tasks: Vec<Task> = (0..t)
        .map(|ti| {
            let mut rng = root.split(ti as u64);
            let mut cols: Vec<Vec<(u32, f32)>> = Vec::with_capacity(d);
            for _ in 0..d {
                let mut rows = rng.choose_distinct(n, k);
                rows.sort_unstable();
                cols.push(
                    rows.into_iter().map(|r| (r as u32, rng.normal() as f32)).collect(),
                );
            }
            let y: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            Task::csc(CscMatrix::from_cols(n, cols), y)
        })
        .collect();
    Dataset { name: format!("sparse{:.0}pct", density * 100.0), d, tasks }
}

/// Sparse-vs-dense DPC score sweep; returns one JSON results entry.
fn bench_density(b: &Bencher, t: usize, n: usize, d: usize, density: f64) -> String {
    let sp = sparse_dataset(t, n, d, density, 0xbead);
    let dn = sp.to_dense_backend();
    let (dref, lmax) = DualRef::at_lambda_max(&sp);
    let (o, delta) = ball(&sp, &dref, 0.4 * lmax);

    let sc_sparse = DpcScreener::new(&sp);
    let sc_dense = DpcScreener::new(&dn);
    let s_stats = b.run(
        &format!("DPC scores CSC   ({:>4.1}% density)", density * 100.0),
        || sc_sparse.scores(&sp, &o, delta),
    );
    let d_stats = b.run(
        &format!("DPC scores dense ({:>4.1}% density)", density * 100.0),
        || sc_dense.scores(&dn, &o, delta),
    );
    let speedup = d_stats.median() / s_stats.median();
    println!("   -> CSC speedup at {:.0}% density: {speedup:.1}x\n", density * 100.0);
    format!(
        "    {{\"density\": {density}, \"dense_median_s\": {:.6e}, \
         \"csc_median_s\": {:.6e}, \"speedup\": {:.2}}}",
        d_stats.median(),
        s_stats.median(),
        speedup
    )
}

fn main() -> anyhow::Result<()> {
    let b = Bencher::default();
    let (t, n, d) = (20usize, 50usize, 2000usize);
    let (ds, _) = synthetic1(&SynthOptions { t, n, d, seed: 3, ..Default::default() });
    let y = ops::y64(&ds);
    println!("== kernel micro-benches (T={t}, N={n}, d={d}) ==\n");

    // correlation sweep: 2*T*N*d flops
    let flops = 2.0 * (t * n * d) as f64;
    let stats = b.run("task_corr (screening sweep, f64 acc)", || ops::task_corr(&ds, &y));
    println!("   -> {:.2} GFLOP/s\n", flops / stats.median() / 1e9);

    // secular solves alone (screening minus the sweep)
    let mut rng = Pcg64::new(7);
    let a_batch: Vec<Vec<f64>> =
        (0..d).map(|_| (0..t).map(|_| rng.normal()).collect()).collect();
    let b2_batch: Vec<Vec<f64>> =
        (0..d).map(|_| (0..t).map(|_| rng.normal().abs() + 0.01).collect()).collect();
    b.run(&format!("qp1qc_max x{d} (Newton secular)"), || {
        let mut acc = 0.0;
        for l in 0..d {
            acc += qp1qc_max(&a_batch[l], &b2_batch[l], 0.7).s;
        }
        acc
    });

    // full screen at one lambda
    let (dref, lmax) = DualRef::at_lambda_max(&ds);
    let screener = DpcScreener::new(&ds);
    let (o, delta) = ball(&ds, &dref, 0.4 * lmax);
    b.run("DPC screen (scores, all features)", || screener.scores(&ds, &o, delta));

    // one FISTA gradient step (forward + corr) on the full problem
    let w = vec![0.01f64; d * t];
    b.run("FISTA grad step (forward + task_corr)", || {
        let r = ops::residual(&ds, &w);
        ops::task_corr(&ds, &r)
    });

    // exact lambda_max
    b.run("lambda_max (exact)", || ops::lambda_max(&ds));

    // kernel layer: scalar vs SIMD dispatch per kernel, panel-blocked vs
    // per-column sweeps, and two end-to-end consumers. The tall shape
    // makes each task matrix (~10 MB) spill L2 so the cache blocking has
    // something to win.
    let (kt, kn, kd) = (4usize, 40_000usize, 64usize);
    println!(
        "\n== kernel layer: scalar vs {} (T={kt}, N={kn}, d={kd}, ACC_BLOCK={}) ==\n",
        simd::active_backend(),
        simd::ACC_BLOCK
    );
    let (kds, _) = synthetic1(&SynthOptions { t: kt, n: kn, d: kd, seed: 5, ..Default::default() });
    let ky = ops::y64(&kds);
    let mut krng = Pcg64::new(0x5edd);
    let ka: Vec<f32> = (0..kn).map(|_| krng.normal() as f32).collect();
    let kb: Vec<f64> = (0..kn).map(|_| krng.normal()).collect();
    let kc: Vec<f64> = (0..kn).map(|_| krng.normal()).collect();
    let spk = kn / 20;
    let sp_idx: Vec<u32> = (0..spk).map(|i| (i * kn / spk) as u32).collect();
    let sp_val: Vec<f32> = (0..spk).map(|_| krng.normal() as f32).collect();
    let mut kz = vec![0.0f64; kn];
    let (kdref, klmax) = DualRef::at_lambda_max(&kds);
    let ksc = DpcScreener::new(&kds);
    let (ko, kdelta) = ball(&kds, &kdref, 0.4 * klmax);
    let kw = vec![0.01f64; kd * kt];
    let mut kernel_rows = vec![
        bench_backends(&b, &format!("dot_mixed n={kn}"), || simd::dot_mixed(&ka, &kb)),
        bench_backends(&b, &format!("dot_f64 n={kn}"), || simd::dot_f64(&kb, &kc)),
        bench_backends(&b, &format!("sp_dot_mixed nnz={spk}"), || {
            simd::sp_dot_mixed(&sp_idx, &sp_val, &kb)
        }),
        bench_backends(&b, &format!("axpy_f64 n={kn}"), || {
            simd::axpy_f64(1.0e-6, &ka, &mut kz);
            kz[0]
        }),
        bench_backends(&b, "task_corr (panel-blocked sweep)", || ops::task_corr(&kds, &ky)),
        bench_backends(&b, "DPC screen e2e (scores, all features)", || {
            ksc.scores(&kds, &ko, kdelta)
        }),
        bench_backends(&b, "FISTA grad step e2e", || {
            let r = ops::residual(&kds, &kw);
            ops::task_corr(&kds, &r)
        }),
    ];
    // panel blocking vs a per-column sweep that re-streams v every column
    // (both on the active backend — isolates the cache effect)
    let naive = b.run("task_corr naive per-column (unpaneled)", || {
        let mut out = vec![0.0f64; kd * kt];
        for (ti, vt) in ky.iter().enumerate() {
            for l in 0..kd {
                out[l * kt + ti] = kds.col(ti, l).dot_mixed(vt);
            }
        }
        out
    });
    let panel = b.run("task_corr panel-blocked (same backend)", || ops::task_corr(&kds, &ky));
    let blk_speedup = naive.median() / panel.median();
    println!("   -> panel blocking: {blk_speedup:.2}x vs per-column\n");
    kernel_rows.push(format!(
        "    {{\"name\": \"task_corr blocking\", \"naive_median_s\": {:.6e}, \
         \"panel_median_s\": {:.6e}, \"speedup\": {:.2}}}",
        naive.median(),
        panel.median(),
        blk_speedup
    ));
    let kernels_json = format!(
        "{{\n  \"bench\": \"kernel_layer_scalar_vs_simd\",\n  \"generated_by\": \
         \"cargo bench --bench kernels\",\n  \"isa\": \"{}\",\n  \"acc_block\": {},\n  \
         \"shape\": {{\"t\": {kt}, \"n\": {kn}, \"d\": {kd}}},\n  \"provisional\": false,\n  \
         \"results\": [\n{}\n  ]\n}}\n",
        simd::active_backend(),
        simd::ACC_BLOCK,
        kernel_rows.join(",\n")
    );
    let kernels_path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.join("BENCH_kernels.json"))
        .unwrap_or_else(|| PathBuf::from("BENCH_kernels.json"));
    std::fs::write(&kernels_path, &kernels_json)?;
    println!("wrote {}", kernels_path.display());

    // sparse-vs-dense DPC score sweep (the backend refactor's headline):
    // same shape, 1% and 5% stored-entry density
    println!("\n== sparse backend: DPC score sweep (T=10, N=400, d=4000) ==\n");
    let mut entries = Vec::new();
    for density in [0.01, 0.05] {
        entries.push(bench_density(&b, 10, 400, 4000, density));
    }
    let json = format!(
        "{{\n  \"bench\": \"dpc_score_sweep_sparse_vs_dense\",\n  \"generated_by\": \
         \"cargo bench --bench kernels\",\n  \"shape\": {{\"t\": 10, \"n\": 400, \"d\": 4000}},\n  \
         \"results\": [\n{}\n  ]\n}}\n",
        entries.join(",\n")
    );
    let out_path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.join("BENCH_sparse.json"))
        .unwrap_or_else(|| PathBuf::from("BENCH_sparse.json"));
    std::fs::write(&out_path, &json)?;
    println!("wrote {}", out_path.display());

    // static vs gap-dynamic screening on the synthetic2 path: the dynamic
    // run pays for its own gap/score sweeps in col_ops, so a win here is a
    // genuine reduction in column-sweep work, not an accounting artifact
    println!("\n== gap-dynamic screening: static vs dynamic (synthetic2 path) ==\n");
    let rows = mtfl_dpc::experiments::gap_dynamic_rows(mtfl_dpc::experiments::Scale::Quick)?;
    for r in &rows {
        println!(
            "   {:<16} epochs {:>8}  col-ops {:>12}  {:>7.2}s  mean rejection {:.3}",
            r.name, r.epochs, r.col_ops, r.secs, r.mean_rejection
        );
    }
    let pick = |name: &str| rows.iter().find(|r| r.name == name);
    if let (Some(s), Some(dny)) = (pick("static-dpc"), pick("dynamic-dpc")) {
        println!(
            "   -> dynamic-dpc col-op saving: {:.1}%\n",
            100.0 * (1.0 - dny.col_ops as f64 / s.col_ops.max(1) as f64)
        );
    }
    let gap_entries: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "    {{\"name\": \"{}\", \"epochs\": {}, \"col_ops\": {}, \
                 \"secs\": {:.3}, \"mean_rejection\": {:.4}}}",
                r.name, r.epochs, r.col_ops, r.secs, r.mean_rejection
            )
        })
        .collect();
    let gap_json = format!(
        "{{\n  \"bench\": \"static_vs_dynamic_gap_screening\",\n  \"generated_by\": \
         \"cargo bench --bench kernels\",\n  \"dataset\": \"synthetic2 (quick scale)\",\n  \
         \"dynamic_every\": {},\n  \"results\": [\n{}\n  ]\n}}\n",
        mtfl_dpc::experiments::DYNAMIC_EVERY,
        gap_entries.join(",\n")
    );
    let gap_path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.join("BENCH_gap.json"))
        .unwrap_or_else(|| PathBuf::from("BENCH_gap.json"));
    std::fs::write(&gap_path, &gap_json)?;
    println!("wrote {}", gap_path.display());

    // penalty seam (DESIGN.md §14): what the trait costs. Each dispatch
    // row times a hot operation through the concrete ℓ2,1 entry point and
    // through PenaltyKind enum dispatch — bit-identical results
    // (rust/tests/penalty_parity.rs), so the ratio is pure seam overhead.
    // The instance rows record the absolute prox cost of the non-ℓ2,1
    // penalties (gowl pays a per-row sort + PAV pass on top of sgl's two
    // thresholds).
    println!("\n== penalty seam: concrete ℓ2,1 vs PenaltyKind dispatch (T={t}, N={n}, d={d}) ==\n");
    let pk = PenaltyKind::L21;
    let pw = vec![0.01f64; d * t];
    let b2 = ds.col_sqnorms();
    let plam = 0.4 * lmax;
    let mut dispatch_rows: Vec<String> = Vec::new();
    let mut dispatch_row = |name: &str, c: f64, s: f64| {
        let overhead = s / c;
        println!("   -> {name}: seam/concrete = {overhead:.3}\n");
        dispatch_rows.push(format!(
            "    {{\"name\": \"{name}\", \"concrete_median_s\": {c:.6e}, \
             \"seam_median_s\": {s:.6e}, \"overhead\": {overhead:.3}}}"
        ));
    };
    let c = b.run("l21 value (ops::l21_norm)", || ops::l21_norm(&pw, t));
    let s = b.run("l21 value (PenaltyKind seam)", || pk.value(&pw, t));
    dispatch_row("value", c.median(), s.median());
    let c = b.run("prox21_inplace (concrete, incl. clone)", || {
        let mut wb = pw.clone();
        mtfl_dpc::solver::prox::prox21_inplace(&mut wb, t, 0.02)
    });
    let s = b.run("prox (PenaltyKind seam, incl. clone)", || {
        let mut wb = pw.clone();
        pk.prox_inplace(&mut wb, t, 0.02)
    });
    dispatch_row("prox", c.median(), s.median());
    let c = b.run("ball_scores (concrete sweep)", || {
        mtfl_dpc::screening::ball_scores(&ds, &b2, &o, delta)
    });
    let s = b.run("ball_scores_for (PenaltyKind seam)", || {
        mtfl_dpc::screening::ball_scores_for(&ds, &b2, &o, delta, &pk)
    });
    dispatch_row("ball_scores", c.median(), s.median());
    let c = b.run("duality_gap (concrete)", || ops::duality_gap(&ds, &pw, plam));
    let s = b.run("duality_gap_for (PenaltyKind seam)", || {
        ops::duality_gap_for(&ds, &pw, plam, &pk)
    });
    dispatch_row("duality_gap", c.median(), s.median());
    let mut instance_rows: Vec<String> = Vec::new();
    for (label, kind) in [
        ("l21", PenaltyKind::L21),
        ("sgl(a=0.3)", PenaltyKind::Sgl { alpha: 0.3 }),
        ("gowl(g=1)", PenaltyKind::Gowl { gamma: 1.0 }),
    ] {
        let st = b.run(&format!("prox {label} (incl. clone)"), || {
            let mut wb = pw.clone();
            kind.prox_inplace(&mut wb, t, 0.02)
        });
        instance_rows.push(format!(
            "    {{\"name\": \"prox {label}\", \"median_s\": {:.6e}}}",
            st.median()
        ));
    }
    let pen_json = format!(
        "{{\n  \"bench\": \"penalty_seam_dispatch_overhead\",\n  \"generated_by\": \
         \"cargo bench --bench kernels\",\n  \"shape\": {{\"t\": {t}, \"n\": {n}, \"d\": {d}}},\n  \
         \"provisional\": false,\n  \"dispatch\": [\n{}\n  ],\n  \"instances\": [\n{}\n  ]\n}}\n",
        dispatch_rows.join(",\n"),
        instance_rows.join(",\n")
    );
    let pen_path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.join("BENCH_penalty.json"))
        .unwrap_or_else(|| PathBuf::from("BENCH_penalty.json"));
    std::fs::write(&pen_path, &pen_json)?;
    println!("wrote {}", pen_path.display());

    // AOT engine micro-benches if artifacts exist
    let dir = PathBuf::from("artifacts");
    if dir.join("manifest.tsv").exists() {
        let engine = AotEngine::new(&dir)?;
        if engine.manifest.config_for(t, n, d).is_some() {
            engine.warmup_config("synth2k")?;
            let x = ds.to_tnd()?;
            let ytn = ds.y_tn()?;
            println!();
            b.run("AOT lammax artifact (PJRT)", || {
                engine.lammax("synth2k", &x, &ytn).unwrap()
            });
            let theta0: Vec<f32> = ytn.iter().map(|&v| v / lmax as f32).collect();
            let lm = engine.lammax("synth2k", &x, &ytn)?;
            b.run("AOT screen artifact (PJRT, incl. marshalling)", || {
                engine
                    .screen("synth2k", &x, &ytn, &theta0, &lm.normal, 0.4 * lm.lam_max)
                    .unwrap()
            });
            let w0 = vec![0.0f32; 250 * t];
            let keep: Vec<usize> = (0..250).collect();
            let xr = mtfl_dpc::runtime::buckets::pack_tnd(&ds.tasks, &keep, 250);
            b.run("AOT fista chunk b250 (50 iters)", || {
                engine
                    .fista_chunk("synth2k", 250, &xr, &ytn, &w0, &w0, 1.0, 0.4 * lm.lam_max, 4000.0)
                    .unwrap()
            });
        } else {
            println!("\n(no synth2k artifacts; skipping AOT micro-benches)");
        }
    } else {
        println!("\n(no artifacts/; skipping AOT micro-benches)");
    }
    Ok(())
}
