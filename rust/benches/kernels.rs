//! Micro-benchmarks of the hot paths on both engines (EXPERIMENTS.md §Perf
//! feeds from this target):
//!
//!   * the correlation sweep `task_corr` (the dominant cost of DPC);
//!   * the per-feature QP1QC secular solve;
//!   * full DPC screen at one λ;
//!   * one FISTA iteration (exact) / one FISTA chunk step (AOT);
//!   * the AOT screen artifact (PJRT end-to-end including marshalling).
//!
//!     cargo bench --bench kernels

use mtfl_dpc::bench::Bencher;
use mtfl_dpc::data::synthetic::{synthetic1, SynthOptions};
use mtfl_dpc::ops;
use mtfl_dpc::runtime::AotEngine;
use mtfl_dpc::screening::dpc::{ball, DpcScreener, DualRef};
use mtfl_dpc::screening::secular::qp1qc_max;
use mtfl_dpc::util::Pcg64;
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let b = Bencher::default();
    let (t, n, d) = (20usize, 50usize, 2000usize);
    let (ds, _) = synthetic1(&SynthOptions { t, n, d, seed: 3, ..Default::default() });
    let y = ops::y64(&ds);
    println!("== kernel micro-benches (T={t}, N={n}, d={d}) ==\n");

    // correlation sweep: 2*T*N*d flops
    let flops = 2.0 * (t * n * d) as f64;
    let stats = b.run("task_corr (screening sweep, f64 acc)", || ops::task_corr(&ds, &y));
    println!("   -> {:.2} GFLOP/s\n", flops / stats.median() / 1e9);

    // secular solves alone (screening minus the sweep)
    let mut rng = Pcg64::new(7);
    let a_batch: Vec<Vec<f64>> =
        (0..d).map(|_| (0..t).map(|_| rng.normal()).collect()).collect();
    let b2_batch: Vec<Vec<f64>> =
        (0..d).map(|_| (0..t).map(|_| rng.normal().abs() + 0.01).collect()).collect();
    b.run(&format!("qp1qc_max x{d} (Newton secular)"), || {
        let mut acc = 0.0;
        for l in 0..d {
            acc += qp1qc_max(&a_batch[l], &b2_batch[l], 0.7).s;
        }
        acc
    });

    // full screen at one lambda
    let (dref, lmax) = DualRef::at_lambda_max(&ds);
    let screener = DpcScreener::new(&ds);
    let (o, delta) = ball(&ds, &dref, 0.4 * lmax);
    b.run("DPC screen (scores, all features)", || screener.scores(&ds, &o, delta));

    // one FISTA gradient step (forward + corr) on the full problem
    let w = vec![0.01f64; d * t];
    b.run("FISTA grad step (forward + task_corr)", || {
        let r = ops::residual(&ds, &w);
        ops::task_corr(&ds, &r)
    });

    // exact lambda_max
    b.run("lambda_max (exact)", || ops::lambda_max(&ds));

    // AOT engine micro-benches if artifacts exist
    let dir = PathBuf::from("artifacts");
    if dir.join("manifest.tsv").exists() {
        let engine = AotEngine::new(&dir)?;
        if engine.manifest.config_for(t, n, d).is_some() {
            engine.warmup_config("synth2k")?;
            let x = ds.to_tnd()?;
            let ytn = ds.y_tn()?;
            println!();
            b.run("AOT lammax artifact (PJRT)", || {
                engine.lammax("synth2k", &x, &ytn).unwrap()
            });
            let theta0: Vec<f32> = ytn.iter().map(|&v| v / lmax as f32).collect();
            let lm = engine.lammax("synth2k", &x, &ytn)?;
            b.run("AOT screen artifact (PJRT, incl. marshalling)", || {
                engine
                    .screen("synth2k", &x, &ytn, &theta0, &lm.normal, 0.4 * lm.lam_max)
                    .unwrap()
            });
            let w0 = vec![0.0f32; 250 * t];
            let keep: Vec<usize> = (0..250).collect();
            let xr = mtfl_dpc::runtime::buckets::pack_tnd(&ds.tasks, &keep, 250);
            b.run("AOT fista chunk b250 (50 iters)", || {
                engine
                    .fista_chunk("synth2k", 250, &xr, &ytn, &w0, &w0, 1.0, 0.4 * lm.lam_max, 4000.0)
                    .unwrap()
            });
        } else {
            println!("\n(no synth2k artifacts; skipping AOT micro-benches)");
        }
    } else {
        println!("\n(no artifacts/; skipping AOT micro-benches)");
    }
    Ok(())
}
