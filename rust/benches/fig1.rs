//! Bench target reproducing **Figure 1**: DPC rejection ratios on
//! Synthetic 1 / Synthetic 2 at three feature dimensions, averaged over
//! trials (paper: 6 panels, ratios > 0.9 everywhere, rising with d).
//!
//!     cargo bench --bench fig1
//!     MTFL_BENCH_SCALE=default cargo bench --bench fig1

use mtfl_dpc::coordinator::path::EngineKind;
use mtfl_dpc::experiments::{run_fig1, Scale};

fn main() -> anyhow::Result<()> {
    let scale = Scale::parse(
        &std::env::var("MTFL_BENCH_SCALE").unwrap_or_else(|_| "quick".into()),
    )?;
    println!("== Figure 1 reproduction (scale: {scale:?}) ==\n");
    println!("{}", run_fig1(scale, &EngineKind::Exact)?);
    Ok(())
}
