//! Executor bench (DESIGN.md §11): (a) the spawn tax — the same chunked
//! sweep dispatched 1 000 times through a spawn-per-call
//! `std::thread::scope` (the pre-executor implementation, kept here as
//! the baseline) vs the persistent pool; (b) the shard prefetch pipeline
//! — one screen-before-load λ-path with prefetch off vs on, with the
//! overlap ledger (hits, stall time). Results land in `BENCH_exec.json`
//! at the repo root.
//!
//!     cargo bench --bench exec

use mtfl_dpc::coordinator::lambda_grid;
use mtfl_dpc::coordinator::path::{
    run_path_sharded, PathOptions, ScreenerKind, ShardRunResult,
};
use mtfl_dpc::data::io::save_sharded;
use mtfl_dpc::data::synthetic::{synthetic1, SynthOptions};
use mtfl_dpc::data::ShardedDataset;
use mtfl_dpc::solver::SolveOptions;
use mtfl_dpc::util::{executor, num_threads, parallel_chunks};
use std::path::PathBuf;
use std::time::Instant;

/// The pre-executor `parallel_chunks`: fresh OS threads per call via
/// `std::thread::scope`. Kept verbatim as the spawn-tax baseline.
fn spawn_per_call_chunks<R, F>(len: usize, max_workers: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize, usize, usize) -> R + Sync,
{
    if len == 0 {
        return Vec::new();
    }
    let workers = max_workers.min(num_threads()).min(len).max(1);
    if workers == 1 {
        return vec![f(0, 0, len)];
    }
    let chunk = len.div_ceil(workers);
    let mut out: Vec<Option<R>> = (0..workers).map(|_| None).collect();
    // repro-lint: allow(no-spawn): this IS the spawn-per-call baseline the bench compares the pooled executor against
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(workers);
        for (i, slot) in out.iter_mut().enumerate() {
            let start = i * chunk;
            let end = ((i + 1) * chunk).min(len);
            let fref = &f;
            handles.push(s.spawn(move || {
                if start < end {
                    *slot = Some(fref(i, start, end));
                }
            }));
        }
        for h in handles {
            h.join().expect("worker panicked");
        }
    });
    out.into_iter().flatten().collect()
}

fn main() -> anyhow::Result<()> {
    let w = num_threads();
    executor::ensure_init();
    println!("== executor bench (num_threads = {w}) ==\n");

    // -- (a) spawn tax: 1k dispatches of one chunked sum-of-squares sweep --
    let data: Vec<f64> = (0..4096).map(|i| (i as f64).sin()).collect();
    let reps = 1000usize;
    let run_spawn = || {
        spawn_per_call_chunks(data.len(), usize::MAX, |_, s, e| {
            data[s..e].iter().map(|v| v * v).sum::<f64>()
        })
        .into_iter()
        .sum::<f64>()
    };
    let run_pool = || {
        parallel_chunks(data.len(), usize::MAX, |_, s, e| {
            data[s..e].iter().map(|v| v * v).sum::<f64>()
        })
        .into_iter()
        .sum::<f64>()
    };
    // warm both paths, and check they agree bit-for-bit
    let a = run_spawn();
    let b = run_pool();
    assert_eq!(a.to_bits(), b.to_bits(), "dispatch paths disagree");

    let t0 = Instant::now();
    let mut acc = 0.0f64;
    for _ in 0..reps {
        acc += run_spawn();
    }
    let spawn_secs = t0.elapsed().as_secs_f64();

    let t0 = Instant::now();
    for _ in 0..reps {
        acc -= run_pool();
    }
    let pool_secs = t0.elapsed().as_secs_f64();
    // the two dispatch paths return bitwise-equal sums (checked above);
    // the accumulator only guards against the optimizer deleting the loop
    assert!(acc.abs() < 1e-3 * a.abs().max(1.0), "sweep accumulators diverged: {acc}");

    let spawn_us = 1e6 * spawn_secs / reps as f64;
    let pool_us = 1e6 * pool_secs / reps as f64;
    println!("spawn-per-call  {spawn_secs:>8.3}s total  {spawn_us:>9.1} us/dispatch");
    println!(
        "executor        {pool_secs:>8.3}s total  {pool_us:>9.1} us/dispatch  \
         ({:.1}x)",
        spawn_us / pool_us.max(1e-9)
    );

    // -- (b) shard path: prefetch off vs on --
    let (t, n, d) = (4usize, 16usize, 2000usize);
    let (ds, _) = synthetic1(&SynthOptions {
        t,
        n,
        d,
        support_frac: 0.05,
        noise: 0.05,
        seed: 42,
    });
    let opts = PathOptions {
        ratios: lambda_grid(12, 1.0, 0.05),
        solve: SolveOptions { tol: 1e-6, ..Default::default() },
        screener: ScreenerKind::Dpc,
        ..Default::default()
    };
    let shard_path = std::env::temp_dir()
        .join(format!("mtfl_bench_exec_{}.mtd3", std::process::id()));
    save_sharded(&ds, &shard_path, 64 << 10)?;

    let run_shard = |prefetch: bool| -> anyhow::Result<ShardRunResult> {
        // fresh open: cold block cache (the OS page cache is warmed for
        // both sides by the warmup run below)
        let sh = ShardedDataset::open(&shard_path)?;
        sh.set_prefetch(prefetch);
        run_path_sharded(&sh, &opts)
    };
    run_shard(false)?; // page-cache warmup, discarded
    let off = run_shard(false)?;
    let on = run_shard(true)?;
    std::fs::remove_file(&shard_path).ok();

    println!("\nshard path (T={t}, N={n}, d={d}, 12-pt grid):");
    println!(
        "prefetch off  {:>7.3}s   stalled {:>7.3}s",
        off.path.total_secs, off.prefetch.stall_secs
    );
    println!(
        "prefetch on   {:>7.3}s   stalled {:>7.3}s   {}/{} prefetches warm",
        on.path.total_secs, on.prefetch.stall_secs, on.prefetch.hits, on.prefetch.issued
    );

    let json = format!(
        "{{\n  \"bench\": \"executor\",\n  \"generated_by\": \
         \"cargo bench --bench exec\",\n  \"provisional\": false,\n  \
         \"num_threads\": {w},\n  \"spawn_tax\": {{\"reps\": {reps}, \
         \"sweep_len\": {}, \"spawn_per_call_us\": {spawn_us:.2}, \
         \"executor_us\": {pool_us:.2}, \"speedup\": {:.2}}},\n  \
         \"shard_prefetch\": {{\"shape\": {{\"t\": {t}, \"n\": {n}, \"d\": {d}}},\n    \
         \"off\": {{\"total_secs\": {:.3}, \"stall_secs\": {:.4}}},\n    \
         \"on\": {{\"total_secs\": {:.3}, \"stall_secs\": {:.4}, \
         \"prefetch_hits\": {}, \"prefetch_issued\": {}}}}}\n}}\n",
        data.len(),
        spawn_us / pool_us.max(1e-9),
        off.path.total_secs,
        off.prefetch.stall_secs,
        on.path.total_secs,
        on.prefetch.stall_secs,
        on.prefetch.hits,
        on.prefetch.issued,
    );
    let out_path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.join("BENCH_exec.json"))
        .unwrap_or_else(|| PathBuf::from("BENCH_exec.json"));
    std::fs::write(&out_path, &json)?;
    println!("\nwrote {}", out_path.display());
    Ok(())
}
