//! Bench target reproducing **Table 1**: running time of the path solver
//! with and without DPC on all five workloads, plus speedup.
//!
//!     cargo bench --bench table1                       (scaled dims)
//!     MTFL_BENCH_SCALE=quick cargo bench --bench table1
//!     MTFL_BENCH_SCALE=paper cargo bench --bench table1 (printed dims; hours)

use mtfl_dpc::coordinator::path::EngineKind;
use mtfl_dpc::experiments::{run_table1, Scale};

fn main() -> anyhow::Result<()> {
    let scale = Scale::parse(
        &std::env::var("MTFL_BENCH_SCALE").unwrap_or_else(|_| "quick".into()),
    )?;
    println!("== Table 1 reproduction (scale: {scale:?}, exact engine) ==");
    println!(
        "paper shape to expect: DPC cost << solver cost; speedup grows with d\n"
    );
    let out = run_table1(scale, &EngineKind::Exact)?;
    println!("{out}");
    Ok(())
}
