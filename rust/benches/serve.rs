//! Serving bench (DESIGN.md §15): drive the `repro load` RPS ramp
//! against an in-process serve daemon — client pacing and server ticks
//! interleaved on one thread through the `idle` hook — and record
//! per-level latency percentiles, the saturation RPS, and the daemon's
//! own per-op stats. Results land in `BENCH_serve.json` at the repo
//! root (`provisional: false` — this file only writes after a real run).
//!
//!     cargo bench --bench serve

use mtfl_dpc::coordinator::path::ScreenerKind;
use mtfl_dpc::experiments::{build_by_name, exp_opts, Scale};
use mtfl_dpc::serve::{proto, run_load, LoadOptions, Server, ServerOptions};
use mtfl_dpc::util::num_threads;
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let w = num_threads();
    println!("== serve bench (num_threads = {w}) ==\n");

    // a mid-size workload: big enough that a predict batch is real work,
    // small enough that the prefit path fits in a bench budget
    let d = 400usize;
    let ds = build_by_name("synth1", d, Scale::Quick, 11)?;
    let opts = ServerOptions {
        path: exp_opts(12, ScreenerKind::Dpc),
        prefit: true,
        max_frame: proto::DEFAULT_MAX_FRAME,
    };
    let mut srv = Server::bind("127.0.0.1:0", ds, opts)?;
    let addr = srv.local_addr()?.to_string();
    let fitted = srv.fitted_ratios();
    let ratio = fitted[fitted.len() / 2];
    println!("daemon on {addr}: {} warm models, predicting at ratio {ratio:.4}", fitted.len());

    let lopts = LoadOptions {
        initial_rps: 50.0,
        increment_rps: 50.0,
        target_rps: 500.0,
        step_secs: 2.0,
        conns: 4,
        rows: 4,
        ratio,
        seed: 0,
        d,
    };
    let report = {
        let srv = &mut srv;
        run_load(&addr, &lopts, &mut || srv.tick().map(|_| ()))?
    };

    println!("\n{:>12} {:>12} {:>8} {:>9} {:>9} {:>9}", "offered", "achieved", "errors", "p50", "p95", "p99");
    for l in &report.levels {
        println!(
            "{:>9.0}/s {:>9.1}/s {:>8} {:>7.2}ms {:>7.2}ms {:>7.2}ms",
            l.offered_rps, l.achieved_rps, l.errors, l.p50_ms, l.p95_ms, l.p99_ms
        );
    }
    match report.saturation_rps {
        Some(rps) => println!("\nsaturated at {rps:.1} req/s achieved"),
        None => println!(
            "\nnever saturated (max achieved {:.1} req/s at target {:.0})",
            report.max_achieved_rps, lopts.target_rps
        ),
    }

    let out = report.to_json(false).to_json();
    let out_path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.join("BENCH_serve.json"))
        .unwrap_or_else(|| PathBuf::from("BENCH_serve.json"));
    std::fs::write(&out_path, format!("{out}\n"))?;
    println!("wrote {}", out_path.display());
    Ok(())
}
