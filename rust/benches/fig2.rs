//! Bench target reproducing **Figure 2**: DPC rejection ratios on the
//! three simulated real datasets (Animal, TDT2, ADNI analogues).
//! Paper shape: all curves > 0.9; ADNI (largest d/N) > 0.99 everywhere.
//!
//!     cargo bench --bench fig2
//!     MTFL_BENCH_SCALE=default cargo bench --bench fig2

use mtfl_dpc::coordinator::path::EngineKind;
use mtfl_dpc::experiments::{run_fig2, Scale};

fn main() -> anyhow::Result<()> {
    let scale = Scale::parse(
        &std::env::var("MTFL_BENCH_SCALE").unwrap_or_else(|_| "quick".into()),
    )?;
    println!("== Figure 2 reproduction (scale: {scale:?}) ==\n");
    println!("{}", run_fig2(scale, &EngineKind::Exact)?);
    Ok(())
}
