//! Bench target for the design-choice ablations (DESIGN.md §8):
//!   ABL1 — exact QP1QC scores vs the Cauchy–Schwarz bound;
//!   ABL2 — sequential (Corollary 9) vs one-shot screening.
//!
//!     cargo bench --bench ablation
//!     MTFL_BENCH_SCALE=default cargo bench --bench ablation

use mtfl_dpc::experiments::{run_ablation, Scale};

fn main() -> anyhow::Result<()> {
    let scale = Scale::parse(
        &std::env::var("MTFL_BENCH_SCALE").unwrap_or_else(|_| "quick".into()),
    )?;
    println!("== screener ablations (scale: {scale:?}) ==\n");
    println!("{}", run_ablation(scale)?);
    Ok(())
}
