//! Screen-before-load bench (DESIGN.md §10): the same λ-path run on the
//! in-RAM dense backend and on an MTD3 shard, recording solver col-ops
//! plus the memory-model numbers — bytes materialized per grid point (the
//! peak-RSS proxy: the matrix memory the solver actually saw) against the
//! bytes a dense in-RAM load would cost. Results land in
//! `BENCH_shard.json` at the repo root.
//!
//!     cargo bench --bench shard

use mtfl_dpc::coordinator::lambda_grid;
use mtfl_dpc::coordinator::path::{
    run_path, run_path_sharded, EngineKind, PathOptions, ScreenerKind,
};
use mtfl_dpc::data::io::save_sharded;
use mtfl_dpc::data::synthetic::{synthetic1, SynthOptions};
use mtfl_dpc::data::ShardedDataset;
use mtfl_dpc::solver::SolveOptions;
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let (t, n, d) = (4usize, 16usize, 2000usize);
    let (ds, _) = synthetic1(&SynthOptions {
        t,
        n,
        d,
        support_frac: 0.05,
        noise: 0.05,
        seed: 42,
    });
    let opts = PathOptions {
        ratios: lambda_grid(12, 1.0, 0.05),
        solve: SolveOptions { tol: 1e-6, ..Default::default() },
        screener: ScreenerKind::Dpc,
        ..Default::default()
    };

    println!("== screen-before-load: dense vs sharded (T={t}, N={n}, d={d}) ==\n");
    let dense = run_path(&ds, &opts, &EngineKind::Exact)?;
    println!(
        "dense    total {:>7.2}s  col-ops {:>12}  resident matrix {:.2} MiB",
        dense.total_secs,
        dense.total_col_ops(),
        ds.mem_bytes() as f64 / (1024.0 * 1024.0)
    );

    let shard_path = std::env::temp_dir()
        .join(format!("mtfl_bench_shard_{}.mtd3", std::process::id()));
    let summary = save_sharded(&ds, &shard_path, 64 << 10)?;
    let sh = ShardedDataset::open(&shard_path)?;
    let sharded = run_path_sharded(&sh, &opts);
    std::fs::remove_file(&shard_path).ok();
    let sharded = sharded?;
    println!(
        "sharded  total {:>7.2}s  col-ops {:>12}  peak materialized {:.2} MiB \
         of {:.2} MiB dense ({:.1}%)",
        sharded.path.total_secs,
        sharded.path.total_col_ops(),
        sharded.peak_materialized_bytes as f64 / (1024.0 * 1024.0),
        sharded.dense_bytes as f64 / (1024.0 * 1024.0),
        100.0 * sharded.peak_materialized_bytes as f64 / sharded.dense_bytes as f64
    );
    println!(
        "         disk: {} blocks x {} cols, {:.2} MiB read over {} block loads\n",
        summary.blocks,
        summary.block_cols,
        sharded.bytes_read as f64 / (1024.0 * 1024.0),
        sharded.blocks_loaded
    );
    println!("   ratio     kept   materialized (% of dense)");
    for (rec, &mb) in sharded.path.records.iter().zip(&sharded.materialized_bytes) {
        println!(
            "   {:.4}  {:>6}   {:>12} ({:>5.1}%)",
            rec.ratio,
            rec.kept,
            mb,
            100.0 * mb as f64 / sharded.dense_bytes as f64
        );
    }

    let per_lambda: Vec<String> = sharded
        .path
        .records
        .iter()
        .zip(&sharded.materialized_bytes)
        .map(|(rec, &mb)| {
            format!(
                "      {{\"ratio\": {:.6}, \"kept\": {}, \"materialized_bytes\": {mb}}}",
                rec.ratio, rec.kept
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"screen_before_load_shard\",\n  \"generated_by\": \
         \"cargo bench --bench shard\",\n  \"provisional\": false,\n  \
         \"shape\": {{\"t\": {t}, \"n\": {n}, \"d\": {d}}},\n  \
         \"shard\": {{\"block_cols\": {}, \"blocks\": {}}},\n  \
         \"dense_bytes\": {},\n  \"dense\": {{\"total_secs\": {:.3}, \"col_ops\": {}}},\n  \
         \"sharded\": {{\"total_secs\": {:.3}, \"col_ops\": {}, \
         \"peak_materialized_bytes\": {}, \"bytes_read\": {}, \"blocks_loaded\": {}, \
         \"per_lambda\": [\n{}\n  ]}}\n}}\n",
        summary.block_cols,
        summary.blocks,
        sharded.dense_bytes,
        dense.total_secs,
        dense.total_col_ops(),
        sharded.path.total_secs,
        sharded.path.total_col_ops(),
        sharded.peak_materialized_bytes,
        sharded.bytes_read,
        sharded.blocks_loaded,
        per_lambda.join(",\n")
    );
    let out_path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.join("BENCH_shard.json"))
        .unwrap_or_else(|| PathBuf::from("BENCH_shard.json"));
    std::fs::write(&out_path, &json)?;
    println!("\nwrote {}", out_path.display());
    Ok(())
}
