//! Distributed shard-sweep bench (DESIGN.md §16): the same λ-path run
//! single-process (`run_path_sharded`) and distributed across 1/2/4
//! worker processes, recording sweep throughput (block-sweeps per
//! second), the reply bytes shipped over the wire, and each worker's
//! disk I/O and busy time — plus a bitwise-parity check against the
//! single-process run at every width. Results land in
//! `BENCH_distrib.json` at the repo root.
//!
//!     cargo bench --bench distrib
//!
//! Workers are spawned here as real `repro worker` subprocesses (the
//! library's `spawn_local` re-executes the *current* binary, which for a
//! bench target is the bench itself, not `repro`), connecting to a
//! bind-and-drop free port — `run_worker`'s connect-retry window makes
//! the start order irrelevant.

use mtfl_dpc::coordinator::path::{
    run_path_sharded, FnObserver, LambdaRecord, PathOptions, ScreenerKind, ShardRunResult,
};
use mtfl_dpc::coordinator::{lambda_grid, run_path_distributed, DistribOptions};
use mtfl_dpc::data::io::save_sharded;
use mtfl_dpc::data::synthetic::{synthetic1, SynthOptions};
use mtfl_dpc::data::ShardedDataset;
use mtfl_dpc::solver::SolveOptions;
use std::net::TcpListener;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};

/// Bind-and-drop a localhost listener to reserve a fresh port; workers
/// retry the connect, so the coordinator re-binding it later is safe.
fn free_addr() -> anyhow::Result<String> {
    let l = TcpListener::bind("127.0.0.1:0")?;
    Ok(l.local_addr()?.to_string())
}

fn spawn_worker(addr: &str) -> anyhow::Result<Child> {
    Ok(Command::new(env!("CARGO_BIN_EXE_repro"))
        .args(["worker", "--connect", addr, "--cache-mb", "64"])
        .stdin(Stdio::null())
        .stdout(Stdio::null())
        .spawn()?)
}

fn run_distributed(
    sh: &ShardedDataset,
    shard_path: &Path,
    opts: &PathOptions,
    n: usize,
) -> anyhow::Result<ShardRunResult> {
    let addr = free_addr()?;
    let mut children: Vec<Child> = Vec::new();
    for _ in 0..n {
        children.push(spawn_worker(&addr)?);
    }
    let dopts = DistribOptions {
        workers: n,
        listen: addr,
        spawn_local: false,
        worker_timeout_secs: 60.0,
        cache_mb: 64,
    };
    let mut noop = FnObserver(|_: f64, _: f64, _: &[f64], _: &LambdaRecord| {});
    let res = run_path_distributed(sh, shard_path, opts, &dopts, &mut noop, None);
    match res {
        Ok(r) => {
            // the coordinator already sent shutdown; reap the exits
            for mut c in children {
                c.wait().ok();
            }
            Ok(r)
        }
        Err(e) => {
            for mut c in children {
                c.kill().ok();
                c.wait().ok();
            }
            Err(e)
        }
    }
}

/// Bit-level parity with the single-process sharded run: λ_max, the
/// final solution, and every grid point's kept count, objective, and gap.
fn bitwise_match(a: &ShardRunResult, b: &ShardRunResult) -> bool {
    a.path.lam_max.to_bits() == b.path.lam_max.to_bits()
        && a.path.last_w.len() == b.path.last_w.len()
        && a.path
            .last_w
            .iter()
            .zip(&b.path.last_w)
            .all(|(x, y)| x.to_bits() == y.to_bits())
        && a.path.records.len() == b.path.records.len()
        && a.path.records.iter().zip(&b.path.records).all(|(r, s)| {
            r.kept == s.kept
                && r.obj.to_bits() == s.obj.to_bits()
                && r.gap.to_bits() == s.gap.to_bits()
        })
}

fn main() -> anyhow::Result<()> {
    let (t, n, d) = (4usize, 16usize, 2000usize);
    let (ds, _) = synthetic1(&SynthOptions {
        t,
        n,
        d,
        support_frac: 0.05,
        noise: 0.05,
        seed: 42,
    });
    let opts = PathOptions {
        ratios: lambda_grid(12, 1.0, 0.05),
        solve: SolveOptions { tol: 1e-6, ..Default::default() },
        screener: ScreenerKind::Dpc,
        ..Default::default()
    };

    let shard_path = std::env::temp_dir()
        .join(format!("mtfl_bench_distrib_{}.mtd3", std::process::id()));
    let summary = save_sharded(&ds, &shard_path, 64 << 10)?;
    let sh = ShardedDataset::open(&shard_path)?;

    println!(
        "== distributed shard sweeps: 1/2/4 workers vs single-process \
         (T={t}, N={n}, d={d}, {} blocks) ==\n",
        summary.blocks
    );
    let single = run_path_sharded(&sh, &opts)?;
    println!(
        "single    total {:>7.2}s  screen {:>6.2}s  {:.2} MiB read over {} block loads",
        single.path.total_secs,
        single.path.screen_secs,
        single.bytes_read as f64 / (1024.0 * 1024.0),
        single.blocks_loaded
    );

    let mut run_rows: Vec<String> = Vec::new();
    for &w in &[1usize, 2, 4] {
        let res = run_distributed(&sh, &shard_path, &opts, w)?;
        let ok = bitwise_match(&res, &single);
        anyhow::ensure!(ok, "distributed run at {w} workers diverged from single-process");
        let blocks_swept: u64 =
            res.workers.iter().map(|l| l.sweeps * l.blocks as u64).sum();
        let bytes_shipped: u64 = res.workers.iter().map(|l| l.bytes_shipped).sum();
        let bytes_read: u64 = res.workers.iter().map(|l| l.bytes_read).sum();
        let blocks_loaded: u64 = res.workers.iter().map(|l| l.blocks_loaded).sum();
        let blocks_per_sec = blocks_swept as f64 / res.path.total_secs.max(1e-9);
        println!(
            "{w} worker{}  total {:>7.2}s  {:>8.0} block-sweeps/s  \
             {:.2} MiB shipped  {:.2} MiB read  bitwise match: {ok}",
            if w == 1 { " " } else { "s" },
            res.path.total_secs,
            blocks_per_sec,
            bytes_shipped as f64 / (1024.0 * 1024.0),
            bytes_read as f64 / (1024.0 * 1024.0),
        );
        let per_worker: Vec<String> = res
            .workers
            .iter()
            .map(|l| {
                format!(
                    "        {{\"blocks\": {}, \"sweeps\": {}, \"bytes_shipped\": {}, \
                     \"bytes_read\": {}, \"blocks_loaded\": {}, \"busy_secs\": {:.4}}}",
                    l.blocks, l.sweeps, l.bytes_shipped, l.bytes_read, l.blocks_loaded,
                    l.busy_secs
                )
            })
            .collect();
        run_rows.push(format!(
            "    {{\"workers\": {w}, \"total_secs\": {:.3}, \"screen_secs\": {:.3}, \
             \"blocks_swept\": {blocks_swept}, \"blocks_per_sec\": {blocks_per_sec:.1}, \
             \"bytes_shipped\": {bytes_shipped}, \"bytes_read\": {bytes_read}, \
             \"blocks_loaded\": {blocks_loaded}, \"bitwise_match\": {ok}, \
             \"per_worker\": [\n{}\n    ]}}",
            res.path.total_secs,
            res.path.screen_secs,
            per_worker.join(",\n")
        ));
    }
    std::fs::remove_file(&shard_path).ok();

    let json = format!(
        "{{\n  \"bench\": \"distrib\",\n  \"generated_by\": \
         \"cargo bench --bench distrib\",\n  \"provisional\": false,\n  \
         \"shape\": {{\"t\": {t}, \"n\": {n}, \"d\": {d}}},\n  \
         \"shard\": {{\"block_cols\": {}, \"blocks\": {}}},\n  \
         \"single\": {{\"total_secs\": {:.3}, \"screen_secs\": {:.3}, \
         \"bytes_read\": {}, \"blocks_loaded\": {}}},\n  \
         \"runs\": [\n{}\n  ]\n}}\n",
        summary.block_cols,
        summary.blocks,
        single.path.total_secs,
        single.path.screen_secs,
        single.bytes_read,
        single.blocks_loaded,
        run_rows.join(",\n")
    );
    let out_path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.join("BENCH_distrib.json"))
        .unwrap_or_else(|| PathBuf::from("BENCH_distrib.json"));
    std::fs::write(&out_path, &json)?;
    println!("\nwrote {}", out_path.display());
    Ok(())
}
